//! Byte-budgeted KV-cache pool: length tiers, free-list reuse, tier
//! migration, and the shared-prefix prefill cache.
//!
//! Every admitted request used to own a full `max_seq`-sized KV device
//! buffer for its whole lifetime, so serving concurrency was capped by
//! worst-case KV memory instead of actual usage.  The pool replaces that
//! with a ladder of fixed KV length **tiers** (doubling from a base up to
//! `max_seq`, mirroring the prefill chunk-bucket machinery): a generation
//! acquires the smallest tier covering its position, **migrates** to the
//! next tier via a device-side copy when it overflows, and releases its
//! tier to a per-tier free list on completion.  Three properties make
//! this safe and cheap (DESIGN.md §Memory):
//!
//! * **Stale-but-masked** — the decode graphs mask attention with
//!   `arange(S) <= pos`, so every KV slot past `pos` is don't-care.
//!   Migration is therefore a plain zero-pad on the sequence dim (the pad
//!   values are never read), and a recycled free-list buffer needs **no
//!   zeroing** before reuse — slot `p` is overwritten by the dispatch at
//!   `pos = p` before the mask ever exposes it.
//! * **Functional dispatches** — every decode/prefill dispatch REPLACES
//!   the KV buffer with a fresh output; inputs are never mutated in
//!   place.  That gives the shared-prefix cache copy-on-write for free: a
//!   cached prefix buffer is handed to a new generation as a shared
//!   (`Rc`) input, and the generation's very first dispatch produces its
//!   own private buffer — no copy dispatch at all.
//! * **Bit-exact tiers** — masked lanes are exactly `-1e30`, so their
//!   softmax contribution is exactly `0.0`: a tier-S dispatch and a
//!   max_seq dispatch produce identical logits for the same `pos`.
//!
//! The pool itself is pure byte accounting, generic over the buffer
//! payload `B` (unit tests use `B = ()`, the runtime uses
//! `B = PjRtBuffer`) — the same shape as `anyprec::MaterializeCache`.
//! Device-side tier casts live in [`KvCaster`], a sibling of
//! `stack::Stacker` that generates pad/copy graphs as HLO text and
//! caches the compiled executables shape-keyed on the [`Runtime`].

use std::cell::RefCell;
use std::collections::HashMap;
use std::fmt::Write as _;
use std::rc::Rc;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{Context, Result};
use xla::PjRtBuffer;

use crate::model::HloEntry;
use crate::runtime::{wrap, Exe, Runtime};

/// Smallest KV tier (sequence positions).  Matches the largest prefill
/// chunk bucket so one full chunk always fits the birth tier.
pub const BASE_TIER: usize = 128;

/// Fraction of the pool budget the prefix cache may occupy (denominator).
const PREFIX_BUDGET_DIV: usize = 4;

/// The doubling tier ladder: `base, 2·base, 4·base, …`, capped at (and
/// always ending exactly on) `max_seq`.
pub fn tier_ladder(max_seq: usize, base: usize) -> Vec<usize> {
    let mut tiers = Vec::new();
    let mut s = base.max(1);
    while s < max_seq {
        tiers.push(s);
        s *= 2;
    }
    tiers.push(max_seq);
    tiers
}

/// Smallest tier in `ladder` with room for `needed` positions.
pub fn tier_for(ladder: &[usize], needed: usize) -> Option<usize> {
    ladder.iter().copied().find(|&s| s >= needed)
}

/// Largest multiple of `quantum` that is `<= prompt_len - 1` — the
/// shareable prefix length for a prompt.  Capped below the full prompt so
/// a prefix-cache hit always leaves at least one final chunk to prefill
/// (the dispatch that produces the first-token logits).
pub fn prefix_quantize(prompt_len: usize, quantum: usize) -> Option<usize> {
    if quantum == 0 || prompt_len <= quantum {
        return None;
    }
    Some((prompt_len - 1) / quantum * quantum)
}

/// Parse a byte count with an optional `k`/`m`/`g` suffix (binary units):
/// `"1048576"`, `"512m"`, `"2g"`.
pub fn parse_bytes(s: &str) -> Result<usize> {
    let t = s.trim().to_ascii_lowercase();
    let (num, mult) = match t.as_bytes().last() {
        Some(b'k') => (&t[..t.len() - 1], 1usize << 10),
        Some(b'm') => (&t[..t.len() - 1], 1usize << 20),
        Some(b'g') => (&t[..t.len() - 1], 1usize << 30),
        _ => (t.as_str(), 1usize),
    };
    let n: usize = num
        .parse()
        .with_context(|| format!("invalid byte count '{s}'"))?;
    Ok(n * mult)
}

/// KV pool budget from `DPLLM_KV_BUDGET_BYTES` (same `k/m/g` suffixes as
/// [`parse_bytes`]); unset or unparsable → `None` (unbounded pool).
pub fn budget_from_env() -> Option<usize> {
    std::env::var("DPLLM_KV_BUDGET_BYTES")
        .ok()
        .and_then(|v| parse_bytes(&v).ok())
}

/// True when the shared-prefix cache is disabled (`DPLLM_NO_PREFIX_CACHE`).
pub fn prefix_cache_disabled() -> bool {
    std::env::var_os("DPLLM_NO_PREFIX_CACHE").is_some()
}

/// Typed capacity error: the byte budget cannot hold another tier.  The
/// serving layer downcasts (`anyhow::Error::is::<PoolExhausted>`) to
/// classify such a rejection as *capacity* (HTTP 503 + `Retry-After`)
/// rather than invalid input (400) — pool exhaustion is transient, a
/// malformed prompt is not.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolExhausted {
    pub in_use: usize,
    pub wanted: usize,
    pub budget: usize,
}

impl std::fmt::Display for PoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "kv pool exhausted: {} bytes in use + {} wanted > {} budget",
            self.in_use, self.wanted, self.budget
        )
    }
}

impl std::error::Error for PoolExhausted {}

/// Point-in-time byte accounting of a [`KvPool`] — the KV half of the
/// combined memory report (`ServingEngine::memory_json`).  Plain data so
/// the metrics layer stays device-free.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MemoryStats {
    /// Pool byte budget (`usize::MAX` = unbounded).
    pub budget: usize,
    /// Bytes hard-committed to live generation tiers.
    pub in_use: usize,
    /// Evictable bytes parked on the tier free lists.
    pub free: usize,
    /// Evictable bytes held by shared-prefix entries.
    pub prefix: usize,
    /// Byte cap on the prefix cache (budget / 4).
    pub prefix_budget: usize,
    /// Live prefix entries.
    pub prefix_entries: usize,
    /// Free-list reuses (acquisitions that skipped a fresh allocation).
    pub reuses: u64,
    /// Prefix entries evicted (LRU, byte pressure).
    pub prefix_evictions: u64,
}

/// A shared-prefix cache hit: the cached KV (shared, immutable — the
/// consumer's first dispatch produces its private copy), the prefix
/// length in tokens, and the tier the buffer is shaped for.
pub struct PrefixHit<B> {
    pub kv: Rc<B>,
    pub len: usize,
    pub tier: usize,
}

struct PrefixEntry<B> {
    kv: Rc<B>,
    len: usize,
    tier: usize,
    bytes: usize,
    stamp: u64,
}

/// Byte-budgeted KV pool: tier free lists + prefix cache + accounting.
///
/// Pure host-side bookkeeping — nothing here touches a device.  `in_use`
/// bytes (live generation tiers) are the only *hard* commitment; free-
/// listed buffers and prefix entries are evictable and are dropped, LRU
/// last, whenever a new acquisition needs the room.
pub struct KvPool<B> {
    budget: usize,
    bytes_per_token: usize,
    in_use: usize,
    free: HashMap<usize, Vec<B>>,
    free_bytes: usize,
    prefix: HashMap<(String, Vec<u32>), PrefixEntry<B>>,
    prefix_bytes: usize,
    prefix_budget: usize,
    clock: u64,
    /// Free-list reuses (acquisitions that skipped a fresh allocation).
    pub reuses: u64,
    /// Prefix entries evicted (LRU, byte pressure).
    pub prefix_evictions: u64,
    /// Prefix entries dropped eagerly because their target identity was
    /// retired by `reconfigure()` (staleness fix, DESIGN.md §Memory).
    pub prefix_invalidations: u64,
}

/// The shared, interior-mutable pool handle the runtime threads through
/// sessions (one executor thread — same `Rc<RefCell<…>>` shape as the
/// weight cache).
pub type SharedKvPool = Rc<RefCell<KvPool<PjRtBuffer>>>;

impl<B> KvPool<B> {
    /// `budget` caps total pool-owned bytes (`usize::MAX` = unbounded,
    /// the tier-1 default); `bytes_per_token` is the KV byte cost of one
    /// sequence position across all layers/heads
    /// (`n_layers · 2 · n_heads · head_dim · 4`).
    pub fn new(budget: usize, bytes_per_token: usize) -> KvPool<B> {
        KvPool {
            budget,
            bytes_per_token: bytes_per_token.max(1),
            in_use: 0,
            free: HashMap::new(),
            free_bytes: 0,
            prefix: HashMap::new(),
            prefix_bytes: 0,
            prefix_budget: budget / PREFIX_BUDGET_DIV,
            clock: 0,
            reuses: 0,
            prefix_evictions: 0,
            prefix_invalidations: 0,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    pub fn tier_bytes(&self, tier: usize) -> usize {
        tier * self.bytes_per_token
    }

    /// Hard-committed bytes (live generation tiers).
    pub fn in_use_bytes(&self) -> usize {
        self.in_use
    }

    /// All pool-owned bytes: live + free-listed + prefix cache.
    pub fn resident_bytes(&self) -> usize {
        self.in_use + self.free_bytes + self.prefix_bytes
    }

    pub fn free_bytes(&self) -> usize {
        self.free_bytes
    }

    pub fn prefix_bytes(&self) -> usize {
        self.prefix_bytes
    }

    pub fn prefix_entries(&self) -> usize {
        self.prefix.len()
    }

    /// The byte-accounting snapshot for memory reports.
    pub fn stats(&self) -> MemoryStats {
        MemoryStats {
            budget: self.budget,
            in_use: self.in_use,
            free: self.free_bytes,
            prefix: self.prefix_bytes,
            prefix_budget: self.prefix_budget,
            prefix_entries: self.prefix.len(),
            reuses: self.reuses,
            prefix_evictions: self.prefix_evictions,
        }
    }

    /// Fraction of the budget hard-committed — the admission-pressure
    /// signal the cost model's downshift rule consumes.  0.0 when the
    /// pool is unbounded.
    pub fn pressure(&self) -> f64 {
        if self.budget == usize::MAX || self.budget == 0 {
            return 0.0;
        }
        self.in_use as f64 / self.budget as f64
    }

    /// Would acquiring `tier` fit the budget?  Only `in_use` counts
    /// against it — free-listed buffers and prefix entries yield.
    pub fn would_admit(&self, tier: usize) -> bool {
        self.in_use.saturating_add(self.tier_bytes(tier)) <= self.budget
    }

    /// Charge `tier` bytes without consuming a free-listed buffer — for
    /// callers whose buffer arrives from a dispatch output (the bucketed
    /// prefill path).  Errors when the budget cannot hold another `tier`.
    pub fn charge(&mut self, tier: usize) -> Result<()> {
        let tb = self.tier_bytes(tier);
        if !self.would_admit(tier) {
            return Err(PoolExhausted {
                in_use: self.in_use,
                wanted: tb,
                budget: self.budget,
            }
            .into());
        }
        self.in_use += tb;
        self.make_room();
        Ok(())
    }

    /// Charge `tier` bytes and hand back a recycled buffer if one is
    /// free-listed (stale contents are fine — see module docs).  `None`
    /// means the caller allocates fresh.  Errors when the budget cannot
    /// hold another `tier`.
    pub fn acquire(&mut self, tier: usize) -> Result<Option<B>> {
        let tb = self.tier_bytes(tier);
        if let Some(buf) = self.free.get_mut(&tier).and_then(Vec::pop) {
            // Reuse moves bytes free -> live; in_use still has to fit.
            if self.in_use.saturating_add(tb) > self.budget {
                self.free.entry(tier).or_default().push(buf);
                return Err(PoolExhausted {
                    in_use: self.in_use,
                    wanted: tb,
                    budget: self.budget,
                }
                .into());
            }
            self.in_use += tb;
            self.free_bytes -= tb;
            self.reuses += 1;
            return Ok(Some(buf));
        }
        self.charge(tier)?;
        Ok(None)
    }

    /// Charge the byte delta of growing `from` → `to` (the migration
    /// path: the old buffer is released separately via
    /// [`KvPool::release`]).  Errors when the grown tier cannot fit.
    pub fn migrate_charge(&mut self, from: usize, to: usize) -> Result<()> {
        let (fb, tb) = (self.tier_bytes(from), self.tier_bytes(to));
        let grown = self.in_use.saturating_sub(fb).saturating_add(tb);
        if grown > self.budget {
            return Err(PoolExhausted {
                in_use: self.in_use,
                wanted: tb.saturating_sub(fb),
                budget: self.budget,
            }
            .into());
        }
        self.in_use = grown;
        self.make_room();
        Ok(())
    }

    /// Credit `tier` bytes back; a returned buffer is free-listed for
    /// reuse when it still fits the budget, dropped otherwise.
    pub fn release(&mut self, tier: usize, buf: Option<B>) {
        let tb = self.tier_bytes(tier);
        self.in_use = self.in_use.saturating_sub(tb);
        if let Some(b) = buf {
            self.donate(tier, b);
        }
    }

    /// Free-list a buffer the pool no longer charges as live — the
    /// outgrown buffer left behind by a tier migration (its bytes were
    /// re-pointed at the new tier by [`KvPool::migrate_charge`]).
    /// Dropped instead when keeping it would overrun the budget.
    pub fn donate(&mut self, tier: usize, buf: B) {
        let tb = self.tier_bytes(tier);
        if self.resident_bytes() + tb <= self.budget {
            self.free.entry(tier).or_default().push(buf);
            self.free_bytes += tb;
        }
    }

    /// Drop evictable bytes (free list first, then LRU prefix entries)
    /// until total residency fits the budget again.
    fn make_room(&mut self) {
        while self.resident_bytes() > self.budget && self.free_bytes > 0 {
            let tier = self
                .free
                .iter()
                .find_map(|(&t, v)| (!v.is_empty()).then_some(t));
            let Some(tier) = tier else { break };
            if self.free.get_mut(&tier).and_then(Vec::pop).is_some() {
                self.free_bytes -= self.tier_bytes(tier);
            }
        }
        while self.resident_bytes() > self.budget && !self.prefix.is_empty() {
            self.evict_coldest_prefix();
        }
    }

    fn evict_coldest_prefix(&mut self) {
        let coldest = self
            .prefix
            .iter()
            .min_by_key(|(_, e)| e.stamp)
            .map(|(k, _)| k.clone());
        if let Some(k) = coldest {
            if let Some(e) = self.prefix.remove(&k) {
                self.prefix_bytes -= e.bytes;
                self.prefix_evictions += 1;
                crate::obs::global_tracer().record(
                    crate::obs::EventKind::PrefixEvict {
                        entries: 1,
                        invalidation: false,
                    },
                );
            }
        }
    }

    /// Longest cached prefix of `ids` for target stack `tag`, probing
    /// quantized lengths (`quantum`, `2·quantum`, …, capped below the
    /// full prompt) from longest down.  A hit refreshes the entry's LRU
    /// stamp and hands out a shared reference to the immutable KV.
    pub fn prefix_lookup(&mut self, tag: &str, ids: &[u32],
                         quantum: usize) -> Option<PrefixHit<B>> {
        let mut q = prefix_quantize(ids.len(), quantum)?;
        self.clock += 1;
        loop {
            let key = (tag.to_string(), ids[..q].to_vec());
            if let Some(e) = self.prefix.get_mut(&key) {
                e.stamp = self.clock;
                return Some(PrefixHit {
                    kv: e.kv.clone(),
                    len: e.len,
                    tier: e.tier,
                });
            }
            if q <= quantum {
                return None;
            }
            q -= quantum;
        }
    }

    /// True when `(tag, ids[..len])` is already cached — callers use it
    /// to skip building a snapshot for an existing entry.
    pub fn prefix_contains(&self, tag: &str, ids: &[u32], len: usize) -> bool {
        len <= ids.len()
            && self
                .prefix
                .contains_key(&(tag.to_string(), ids[..len].to_vec()))
    }

    /// Insert an immutable prefix snapshot (`len` tokens, KV shaped for
    /// `tier`).  First writer wins; cold entries are LRU-evicted to keep
    /// the cache within its budget share.
    pub fn prefix_insert(&mut self, tag: &str, ids: &[u32], len: usize,
                         tier: usize, kv: Rc<B>) {
        if len > ids.len() || self.prefix_contains(tag, ids, len) {
            return;
        }
        let bytes = self.tier_bytes(tier);
        if bytes > self.prefix_budget {
            return;
        }
        while self.prefix_bytes + bytes > self.prefix_budget
            && !self.prefix.is_empty()
        {
            self.evict_coldest_prefix();
        }
        self.clock += 1;
        self.prefix_bytes += bytes;
        self.prefix.insert(
            (tag.to_string(), ids[..len].to_vec()),
            PrefixEntry { kv, len, tier, bytes, stamp: self.clock },
        );
    }

    /// Drop every prefix entry published under target identity `tag`
    /// (`"model:target"`), returning how many were removed.  Called by
    /// `ServingEngine::reconfigure` when a target leaves the adaptation
    /// set: a retired tag can never be looked up again, so its entries
    /// would only strand pool bytes (and device KV buffers) until LRU
    /// pressure aged them out.  Counted by `prefix_invalidations`,
    /// distinct from `prefix_evictions` (LRU pressure).
    pub fn invalidate_tag(&mut self, tag: &str) -> usize {
        let stale: Vec<(String, Vec<u32>)> = self
            .prefix
            .keys()
            .filter(|(t, _)| t == tag)
            .cloned()
            .collect();
        for k in &stale {
            if let Some(e) = self.prefix.remove(k) {
                self.prefix_bytes -= e.bytes;
                self.prefix_invalidations += 1;
            }
        }
        if !stale.is_empty() {
            crate::obs::global_tracer().record(
                crate::obs::EventKind::PrefixEvict {
                    entries: stale.len() as u32,
                    invalidation: true,
                },
            );
        }
        stale.len()
    }
}

/// Grow a host-resident KV cache `[l, 2, h, from, d]` → `[l, 2, h, to, d]`
/// by zero-padding the sequence dim — the host fallback for tier
/// migration (pad values are don't-care under the `arange(S) <= pos`
/// mask, zeros keep it deterministic).
pub fn host_grow(data: &[f32], l: usize, h: usize, d: usize, from: usize,
                 to: usize) -> Vec<f32> {
    let slabs = l * 2 * h;
    debug_assert_eq!(data.len(), slabs * from * d);
    let mut out = Vec::with_capacity(slabs * to * d);
    for s in 0..slabs {
        out.extend_from_slice(&data[s * from * d..(s + 1) * from * d]);
        out.resize(out.len() + (to - from) * d, 0.0);
    }
    out
}

/// Device-side KV tier casts: `[l, 2, h, from, d]` → `[l, 2, h, to, d]`
/// as a zero-pad graph (`from == to` is a plain copy), generated as HLO
/// text and compiled once per shape (cached on the [`Runtime`], failure
/// memoized).  Falls back to `None` — callers then take the
/// download/grow/upload host path — when generation or compilation fails
/// or `DPLLM_NO_DEVICE_STACK` disables runtime-generated device graphs.
pub struct KvCaster {
    rt: Arc<Runtime>,
}

impl KvCaster {
    pub fn new(rt: Arc<Runtime>) -> KvCaster {
        KvCaster { rt }
    }

    /// Cast `kv` from tier `from` to tier `to` on the device.  `None`
    /// when the device path is unavailable for this shape.
    pub fn cast(&self, dims: (usize, usize, usize), from: usize, to: usize,
                kv: &PjRtBuffer) -> Option<PjRtBuffer> {
        let exe = self.exe_for(dims, from, to)?;
        match exe.run_buffers(&[kv]) {
            Ok(mut replica) if replica.len() == 1 => replica.pop(),
            _ => None,
        }
    }

    /// True when the device cast graph for this shape compiles.
    pub fn device_side(&self, dims: (usize, usize, usize), from: usize,
                       to: usize) -> bool {
        self.exe_for(dims, from, to).is_some()
    }

    fn exe_for(&self, (l, h, d): (usize, usize, usize), from: usize,
               to: usize) -> Option<Arc<Exe>> {
        if std::env::var_os("DPLLM_NO_DEVICE_STACK").is_some() {
            return None;
        }
        let key = (l, h, d, from, to);
        let mut cache = self.rt.kv_exes.lock().unwrap();
        if let Some(e) = cache.get(&key) {
            return e.clone();
        }
        let built = self.build_exe(l, h, d, from, to).ok();
        cache.insert(key, built.clone());
        built
    }

    /// Parse + compile directly against the PJRT client (NOT
    /// `Runtime::load` — that cache is keyed by path forever and these
    /// temp paths are process-unique; the compiled Exe goes into the
    /// shape-keyed `kv_exes` map instead).  Same temp-path discipline as
    /// `stack::Stacker::build_exe`.
    fn build_exe(&self, l: usize, h: usize, d: usize, from: usize,
                 to: usize) -> Result<Arc<Exe>> {
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let text = kv_cast_hlo_text(l, h, d, from, to);
        let path = std::env::temp_dir().join(format!(
            "dpllm_kvcast_{l}x{h}x{d}_{from}to{to}_{}_{seq}.hlo",
            std::process::id()
        ));
        std::fs::write(&path, text)
            .with_context(|| format!("writing {}", path.display()))?;
        let entry = HloEntry {
            path: path.to_string_lossy().into_owned(),
            args: vec!["p0".into()],
            outputs: vec!["kv".into()],
        };
        let compiled = (|| -> Result<Arc<Exe>> {
            let proto = xla::HloModuleProto::from_text_file(&entry.path)
                .map_err(wrap)
                .with_context(|| format!("parsing {}", entry.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .rt
                .client
                .compile(&comp)
                .map_err(wrap)
                .with_context(|| format!("compiling {}", entry.path))?;
            Ok(Arc::new(Exe { exe, entry: entry.clone() }))
        })();
        let _ = std::fs::remove_file(&path);
        compiled
    }
}

/// HLO text of the tier cast: zero high-pad on the sequence dim (dim 3),
/// or a plain `copy` when `from == to` (prefix snapshot).
fn kv_cast_hlo_text(l: usize, h: usize, d: usize, from: usize,
                    to: usize) -> String {
    let src = format!("f32[{l},2,{h},{from},{d}]{{4,3,2,1,0}}");
    let dst = format!("f32[{l},2,{h},{to},{d}]{{4,3,2,1,0}}");
    let mut s = String::new();
    let _ = writeln!(s, "HloModule kvcast_{l}x{h}x{d}_{from}to{to}\n");
    let _ = writeln!(s, "ENTRY %main {{");
    let _ = writeln!(s, "  %p0 = {src} parameter(0)");
    if from == to {
        let _ = writeln!(s, "  ROOT %kv = {dst} copy({src} %p0)");
    } else {
        let _ = writeln!(s, "  %zero = f32[] constant(0)");
        let _ = writeln!(
            s,
            "  ROOT %kv = {dst} pad({src} %p0, f32[] %zero), \
             padding=0_0x0_0x0_0x0_{}x0_0",
            to - from
        );
    }
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_ladder_doubles_and_caps_at_max_seq() {
        assert_eq!(tier_ladder(640, 128), vec![128, 256, 512, 640]);
        assert_eq!(tier_ladder(512, 128), vec![128, 256, 512]);
        assert_eq!(tier_ladder(100, 128), vec![100]);
        assert_eq!(tier_for(&[128, 256, 640], 1), Some(128));
        assert_eq!(tier_for(&[128, 256, 640], 129), Some(256));
        assert_eq!(tier_for(&[128, 256, 640], 641), None);
    }

    #[test]
    fn prefix_quantize_caps_below_full_prompt() {
        // 300 tokens at quantum 128: shareable prefix is 256 — the final
        // chunk (tokens 256..300) must stay uncached so a hit still runs
        // the logits-producing dispatch.
        assert_eq!(prefix_quantize(300, 128), Some(256));
        // An exact multiple shares one quantum less than the whole.
        assert_eq!(prefix_quantize(256, 128), Some(128));
        assert_eq!(prefix_quantize(129, 128), Some(128));
        assert_eq!(prefix_quantize(128, 128), None);
        assert_eq!(prefix_quantize(5, 0), None);
    }

    #[test]
    fn parse_bytes_suffixes() {
        assert_eq!(parse_bytes("4096").unwrap(), 4096);
        assert_eq!(parse_bytes("64k").unwrap(), 64 << 10);
        assert_eq!(parse_bytes("512M").unwrap(), 512 << 20);
        assert_eq!(parse_bytes("2g").unwrap(), 2 << 30);
        assert!(parse_bytes("lots").is_err());
    }

    /// Free-list reuse: release hands the buffer back to the next
    /// same-tier acquisition without growing residency.
    #[test]
    fn free_list_reuse_skips_fresh_allocation() {
        let mut p: KvPool<u32> = KvPool::new(usize::MAX, 10);
        assert!(p.acquire(128).unwrap().is_none()); // fresh
        assert_eq!(p.in_use_bytes(), 1280);
        p.release(128, Some(7));
        assert_eq!(p.in_use_bytes(), 0);
        assert_eq!(p.free_bytes(), 1280);
        assert_eq!(p.acquire(128).unwrap(), Some(7)); // recycled
        assert_eq!(p.reuses, 1);
        assert_eq!(p.in_use_bytes(), 1280);
        assert_eq!(p.free_bytes(), 0);
        // A different tier misses the free list.
        p.release(128, Some(9));
        assert!(p.acquire(256).unwrap().is_none());
    }

    /// Byte accounting: admission bounds, migration delta, release credit.
    #[test]
    fn budget_accounting_bounds_admission() {
        // budget = 1000 bytes, 1 byte/token.
        let mut p: KvPool<()> = KvPool::new(1000, 1);
        assert!(p.would_admit(640));
        assert!(p.acquire(640).unwrap().is_none());
        assert!(!p.would_admit(640));
        assert!(p.would_admit(256));
        assert!(p.acquire(640).is_err());
        assert!(p.acquire(256).unwrap().is_none());
        assert_eq!(p.in_use_bytes(), 896);
        // 256 -> 512 would need 896 - 256 + 512 = 1152 > 1000.
        assert!(p.migrate_charge(256, 512).is_err());
        p.release(640, None);
        assert!(p.migrate_charge(256, 512).is_ok());
        assert_eq!(p.in_use_bytes(), 512);
        assert_eq!(p.pressure(), 0.512);
        // Free-listed bytes yield: a buffer that no longer fits is dropped.
        p.release(512, Some(()));
        assert_eq!(p.free_bytes(), 512);
        assert!(p.acquire(640).unwrap().is_none());
        assert_eq!(p.free_bytes(), 0, "free list evicted to fit the budget");
        assert!(p.resident_bytes() <= 1000);
    }

    /// Unbounded pools report zero pressure and admit everything.
    #[test]
    fn unbounded_pool_never_rejects() {
        let mut p: KvPool<()> = KvPool::new(usize::MAX, 1 << 20);
        for _ in 0..100 {
            assert!(p.acquire(640).is_ok());
        }
        assert_eq!(p.pressure(), 0.0);
    }

    /// LRU eviction of cold prefix entries under the prefix byte budget.
    #[test]
    fn prefix_cache_lru_evicts_coldest() {
        // budget 1024 -> prefix budget 256; tier 64 at 1 B/token = 64 B
        // per entry -> 4 entries fit.
        let mut p: KvPool<()> = KvPool::new(1024, 1);
        let ids: Vec<u32> = (0..200).collect();
        for len in [64usize, 128, 192] {
            p.prefix_insert("4.0", &ids, len, 64, Rc::new(()));
        }
        assert_eq!(p.prefix_entries(), 3);
        // Touch the len=64 entry so len=128 becomes the coldest.
        assert!(p.prefix_lookup("4.0", &ids[..65], 64).is_some());
        p.prefix_insert("4.0", &ids[..100], 96, 64, Rc::new(()));
        p.prefix_insert("8.0", &ids, 64, 64, Rc::new(()));
        assert_eq!(p.prefix_entries(), 4);
        assert_eq!(p.prefix_evictions, 1);
        assert!(p.prefix_lookup("4.0", &ids[..65], 64).is_some(),
                "recently-touched entry survived");
        // The cold len=128 entry is gone: a 129-token prompt now falls
        // back to its 64-token prefix.
        let hit = p.prefix_lookup("4.0", &ids[..129], 64).unwrap();
        assert_eq!(hit.len, 64);
    }

    /// Longest-prefix probing and stack-identity keying.
    #[test]
    fn prefix_lookup_probes_longest_first_and_keys_on_tag() {
        let mut p: KvPool<()> = KvPool::new(usize::MAX, 1);
        let ids: Vec<u32> = (0..300).collect();
        p.prefix_insert("4.0", &ids, 128, 128, Rc::new(()));
        p.prefix_insert("4.0", &ids, 256, 256, Rc::new(()));
        let hit = p.prefix_lookup("4.0", &ids, 128).unwrap();
        assert_eq!((hit.len, hit.tier), (256, 256));
        // Other stack identity: no sharing across precision targets.
        assert!(p.prefix_lookup("8.0", &ids, 128).is_none());
        // Diverging tokens past the first quantum: falls back to 128.
        let mut other = ids.clone();
        other[200] = 9999;
        assert_eq!(p.prefix_lookup("4.0", &other, 128).unwrap().len, 128);
        // First writer wins: re-inserting under a live key is a no-op.
        p.prefix_insert("4.0", &ids, 256, 256, Rc::new(()));
        assert_eq!(p.prefix_entries(), 2);
    }

    /// `reconfigure()` staleness fix: retiring a target invalidates its
    /// prefix entries eagerly instead of stranding them until LRU
    /// eviction, and only that target's — siblings keep their bytes.
    #[test]
    fn invalidate_tag_drops_only_retired_targets_entries() {
        let mut p: KvPool<()> = KvPool::new(usize::MAX, 1);
        let ids: Vec<u32> = (0..300).collect();
        p.prefix_insert("m:4.00", &ids, 128, 128, Rc::new(()));
        p.prefix_insert("m:4.00", &ids, 256, 256, Rc::new(()));
        p.prefix_insert("m:3.50", &ids, 128, 128, Rc::new(()));
        let before = p.prefix_bytes();
        assert_eq!(p.invalidate_tag("m:4.00"), 2);
        assert_eq!(p.prefix_entries(), 1);
        assert_eq!(p.prefix_invalidations, 2);
        assert_eq!(p.prefix_evictions, 0, "invalidation is not an eviction");
        assert_eq!(p.prefix_bytes(), before - 128 - 256,
                   "bytes credited back on invalidation");
        // The retired tag's entries can never be hit again…
        assert!(p.prefix_lookup("m:4.00", &ids, 128).is_none());
        // …while the surviving sibling still hits.
        assert!(p.prefix_lookup("m:3.50", &ids, 128).is_some());
        // Re-introducing the tag republishes cleanly from scratch.
        p.prefix_insert("m:4.00", &ids, 128, 128, Rc::new(()));
        assert_eq!(p.prefix_lookup("m:4.00", &ids, 128).unwrap().len, 128);
        assert_eq!(p.invalidate_tag("m:9.99"), 0, "unknown tag is a no-op");
    }

    #[test]
    fn host_grow_pads_sequence_dim_with_zeros() {
        // l=1, h=1, d=2, from=2 -> to=4: two slabs (k and v).
        let data: Vec<f32> = (1..=8).map(|x| x as f32).collect();
        let out = host_grow(&data, 1, 1, 2, 2, 4);
        assert_eq!(out.len(), 16);
        assert_eq!(&out[..4], &[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(&out[4..8], &[0.0; 4]);
        assert_eq!(&out[8..12], &[5.0, 6.0, 7.0, 8.0]);
        assert_eq!(&out[12..], &[0.0; 4]);
    }

    #[test]
    fn kv_cast_hlo_text_pad_shape() {
        let t = kv_cast_hlo_text(2, 4, 8, 256, 512);
        assert!(t.contains("HloModule kvcast_2x4x8_256to512"));
        assert!(t.contains("%p0 = f32[2,2,4,256,8]{4,3,2,1,0} parameter(0)"));
        assert!(t.contains("%zero = f32[] constant(0)"));
        assert!(t.contains(
            "ROOT %kv = f32[2,2,4,512,8]{4,3,2,1,0} \
             pad(f32[2,2,4,256,8]{4,3,2,1,0} %p0, f32[] %zero), \
             padding=0_0x0_0x0_0x0_256x0_0"
        ));
    }

    #[test]
    fn kv_cast_hlo_text_same_tier_is_copy() {
        let t = kv_cast_hlo_text(2, 4, 8, 256, 256);
        assert!(t.contains("ROOT %kv = f32[2,2,4,256,8]{4,3,2,1,0} copy("));
        assert!(!t.contains(" pad("));
    }
}
