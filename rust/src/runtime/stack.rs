//! Device-side assembly of per-layer weight slabs into the `[L, out, in]`
//! stacked inputs the decode/prefill graphs expect.
//!
//! The AOT graphs take each group's weight stack as ONE parameter, but the
//! materialization cache (`anyprec::materialize`) holds *per-layer*
//! buffers so a precision rebind re-uploads only the changed layers.  The
//! bridge is a trivial concat graph, generated here as HLO **text** (the
//! repo's interchange format, DESIGN.md §5) and compiled through the same
//! `Runtime::load` path as the real artifacts: L parameters of shape
//! `[1, out, in]`, one `concatenate` on dim 0.  Executing it is a
//! device-to-device copy — no host traffic — so a rebind that changes k of
//! L layers uploads O(k) weight bytes (`TransferStats::assemblies` counts
//! these device-side rebuilds).
//!
//! Degradation: if HLO generation, compilation, or execution fails (or
//! `DPLLM_NO_DEVICE_STACK` is set), the stack is assembled on the host
//! from the cached slabs and uploaded whole — correct, but O(L) upload —
//! and the failing shape is remembered so it is not retried.

use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use anyhow::{bail, Context, Result};
use xla::PjRtBuffer;

use crate::model::HloEntry;
use crate::runtime::{wrap, Exe, Runtime};

pub struct Stacker {
    rt: Arc<Runtime>,
}

impl Stacker {
    pub fn new(rt: Arc<Runtime>) -> Stacker {
        Stacker { rt }
    }

    /// Assemble a `[l, out, in]` stack, in layer order.  With `layers`
    /// holding `l` device buffers of shape `[1, out, in]`, assembly is a
    /// device-side concat; with `layers` empty (the caller skipped
    /// per-layer uploads because the device path is unavailable), the
    /// `hosts` slabs are concatenated on the host and uploaded whole.
    pub fn stack(&self, dims: (usize, usize, usize), layers: &[&PjRtBuffer],
                 hosts: &[&[f32]]) -> Result<PjRtBuffer> {
        let (l, out, inn) = dims;
        if l == 0 || hosts.len() != l || (layers.len() != l && !layers.is_empty()) {
            bail!("stack arity: {} buffers / {} slabs for L={l}",
                  layers.len(), hosts.len());
        }
        if layers.len() == l {
            if let Some(exe) = self.exe_for(l, out, inn) {
                // Device path: a run failure (e.g. donated/poisoned buffer)
                // falls through to the host assembly rather than aborting
                // the rebind.
                if let Ok(mut replica) = exe.run_buffers(layers) {
                    if replica.len() == 1 {
                        self.rt.transfers().count_assembly();
                        return Ok(replica.pop().expect("one output"));
                    }
                }
            }
        }
        let mut data = Vec::with_capacity(l * out * inn);
        for h in hosts {
            if h.len() != out * inn {
                bail!("host slab holds {} elements, wants {}", h.len(), out * inn);
            }
            data.extend_from_slice(h);
        }
        self.rt.upload_f32(&[l, out, inn], &data)
    }

    /// True when the device-side concat graph for `dims` is compiled and
    /// ready (compiles on first ask).  Callers use this to decide whether
    /// per-layer device mirrors are worth uploading at all.
    pub fn device_side(&self, dims: (usize, usize, usize)) -> bool {
        self.exe_for(dims.0, dims.1, dims.2).is_some()
    }

    fn exe_for(&self, l: usize, out: usize, inn: usize) -> Option<Arc<Exe>> {
        if std::env::var_os("DPLLM_NO_DEVICE_STACK").is_some() {
            return None;
        }
        // Shape-keyed, process-wide (lives on Runtime): sibling sessions
        // share one compile per shape, and a failed build is remembered so
        // the host fallback isn't preceded by a doomed compile each time.
        let mut cache = self.rt.stack_exes.lock().unwrap();
        if let Some(e) = cache.get(&(l, out, inn)) {
            return e.clone();
        }
        let built = self.build_exe(l, out, inn).ok();
        cache.insert((l, out, inn), built.clone());
        built
    }

    /// Parse + compile the concat graph directly against the PJRT client.
    /// Deliberately NOT routed through `Runtime::load`: that cache is
    /// keyed by path forever, and these temp paths are process-unique —
    /// caching them there would grow the runtime cache without bound as
    /// sessions come and go.  The compiled Exe goes into Runtime's
    /// shape-keyed `stack_exes` map instead (one entry per distinct
    /// shape, process-wide).
    fn build_exe(&self, l: usize, out: usize, inn: usize) -> Result<Arc<Exe>> {
        // Process-unique sequence on top of the pid: concurrent Stackers
        // (parallel test threads, sibling sessions) must never share a
        // path — a mid-parse rewrite or removal by a sibling would fail
        // this compile and permanently disable the O(k) device path for
        // the shape.
        static SEQ: AtomicU64 = AtomicU64::new(0);
        let seq = SEQ.fetch_add(1, Ordering::Relaxed);
        let text = stack_hlo_text(l, out, inn);
        let path = std::env::temp_dir().join(format!(
            "dpllm_stack_{l}x{out}x{inn}_{}_{seq}.hlo",
            std::process::id()
        ));
        std::fs::write(&path, text)
            .with_context(|| format!("writing {}", path.display()))?;
        let entry = HloEntry {
            path: path.to_string_lossy().into_owned(),
            args: (0..l).map(|p| format!("p{p}")).collect(),
            outputs: vec!["stack".into()],
        };
        let compiled = (|| -> Result<Arc<Exe>> {
            let proto = xla::HloModuleProto::from_text_file(&entry.path)
                .map_err(wrap)
                .with_context(|| format!("parsing {}", entry.path))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .rt
                .client
                .compile(&comp)
                .map_err(wrap)
                .with_context(|| format!("compiling {}", entry.path))?;
            Ok(Arc::new(Exe { exe, entry: entry.clone() }))
        })();
        // The text only feeds the one-shot parse; don't litter temp_dir.
        let _ = std::fs::remove_file(&path);
        compiled
    }
}

/// HLO text of the concat graph: L params `f32[1,out,in]` → `[L,out,in]`.
fn stack_hlo_text(l: usize, out: usize, inn: usize) -> String {
    let part = format!("f32[1,{out},{inn}]{{2,1,0}}");
    let mut s = String::new();
    let _ = writeln!(s, "HloModule stack_{l}x{out}x{inn}\n");
    let _ = writeln!(s, "ENTRY %main {{");
    for p in 0..l {
        let _ = writeln!(s, "  %p{p} = {part} parameter({p})");
    }
    if l == 1 {
        let _ = writeln!(s, "  ROOT %stack = {part} copy({part} %p0)");
    } else {
        let operands: Vec<String> =
            (0..l).map(|p| format!("{part} %p{p}")).collect();
        let _ = writeln!(
            s,
            "  ROOT %stack = f32[{l},{out},{inn}]{{2,1,0}} concatenate({}), dimensions={{0}}",
            operands.join(", ")
        );
    }
    let _ = writeln!(s, "}}");
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hlo_text_shape() {
        let t = stack_hlo_text(2, 4, 8);
        assert!(t.contains("HloModule stack_2x4x8"));
        assert!(t.contains("%p0 = f32[1,4,8]{2,1,0} parameter(0)"));
        assert!(t.contains("%p1 = f32[1,4,8]{2,1,0} parameter(1)"));
        assert!(t.contains(
            "ROOT %stack = f32[2,4,8]{2,1,0} concatenate(f32[1,4,8]{2,1,0} %p0, \
             f32[1,4,8]{2,1,0} %p1), dimensions={0}"
        ));
    }

    #[test]
    fn hlo_text_single_layer_is_copy() {
        let t = stack_hlo_text(1, 3, 16);
        assert!(t.contains("ROOT %stack = f32[1,3,16]{2,1,0} copy("));
        assert!(!t.contains("concatenate"));
    }
}
