//! Minimal JSON parser / writer.
//!
//! serde_json is not available in the offline crate cache, so the artifact
//! manifests, calibration configs and task files are read through this
//! hand-rolled implementation.  It supports the full JSON grammar with the
//! usual relaxations none (strict), parses numbers as f64 (i64 preserved
//! where exact), and keeps object key order for stable round-trips.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// BTreeMap keeps deterministic ordering; fine for our configs.
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors -----------------------------------------------------
    pub fn obj() -> Json {
        Json::Obj(BTreeMap::new())
    }

    pub fn set(&mut self, key: &str, v: impl Into<Json>) -> &mut Self {
        if let Json::Obj(m) = self {
            m.insert(key.to_string(), v.into());
        }
        self
    }

    // ---- accessors ---------------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key '{key}'"))
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => bail!("expected number, got {self:?}"),
        }
    }

    pub fn as_i64(&self) -> Result<i64> {
        Ok(self.as_f64()? as i64)
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("expected string, got {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => bail!("expected bool, got {self:?}"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(a) => Ok(a),
            _ => bail!("expected array, got {self:?}"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("expected object, got {self:?}"),
        }
    }

    /// `[1.5, 2, 3]` -> `Vec<f64>` (convenience for numeric config arrays).
    pub fn as_f64_vec(&self) -> Result<Vec<f64>> {
        self.as_arr()?.iter().map(|v| v.as_f64()).collect()
    }

    pub fn as_f32_vec(&self) -> Result<Vec<f32>> {
        Ok(self.as_f64_vec()?.into_iter().map(|x| x as f32).collect())
    }

    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    /// Fetch `key` and convert, with key context on errors.
    pub fn f64_of(&self, key: &str) -> Result<f64> {
        self.req(key)?.as_f64().with_context(|| format!("key '{key}'"))
    }

    pub fn usize_of(&self, key: &str) -> Result<usize> {
        Ok(self.f64_of(key)? as usize)
    }

    pub fn str_of(&self, key: &str) -> Result<String> {
        Ok(self.req(key)?.as_str().with_context(|| format!("key '{key}'"))?.to_string())
    }

    // ---- parsing -----------------------------------------------------------
    pub fn parse(s: &str) -> Result<Json> {
        let b = s.as_bytes();
        let mut p = Parser { b, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn parse_file(path: &str) -> Result<Json> {
        let s = std::fs::read_to_string(path)
            .with_context(|| format!("reading {path}"))?;
        Json::parse(&s).with_context(|| format!("parsing {path}"))
    }

    // ---- writing -----------------------------------------------------------
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl From<Vec<Json>> for Json {
    fn from(x: Vec<Json>) -> Json {
        Json::Arr(x)
    }
}
impl From<&[f64]> for Json {
    fn from(x: &[f64]) -> Json {
        Json::Arr(x.iter().map(|&v| Json::Num(v)).collect())
    }
}
impl From<&[f32]> for Json {
    fn from(x: &[f32]) -> Json {
        Json::Arr(x.iter().map(|&v| Json::Num(v as f64)).collect())
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b
            .get(self.i)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.i)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => Ok(Json::Str(self.string()?)),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            out.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.i += 1,
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let cp = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.i += 4;
                            // Surrogate pairs.
                            let ch = if (0xD800..0xDC00).contains(&cp) {
                                if self.b.get(self.i) == Some(&b'\\')
                                    && self.b.get(self.i + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .b
                                        .get(self.i + 2..self.i + 6)
                                        .ok_or_else(|| anyhow!("bad surrogate"))?;
                                    let lo = u32::from_str_radix(std::str::from_utf8(hex2)?, 16)?;
                                    self.i += 6;
                                    let c = 0x10000 + ((cp - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(c)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            s.push(ch.unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape '\\{}'", e as char),
                    }
                }
                c if c < 0x80 => s.push(c as char),
                c => {
                    // Re-decode multi-byte UTF-8.
                    let start = self.i - 1;
                    let len = match c {
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let chunk = self
                        .b
                        .get(start..start + len)
                        .ok_or_else(|| anyhow!("bad utf-8"))?;
                    s.push_str(std::str::from_utf8(chunk)?);
                    self.i = start + len;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let x: f64 = s
            .parse()
            .map_err(|_| anyhow!("invalid number '{s}' at byte {start}"))?;
        Ok(Json::Num(x))
    }
}

/// Parse a JSONL file into a vector of objects.
pub fn parse_jsonl(path: &str) -> Result<Vec<Json>> {
    let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
    text.lines()
        .filter(|l| !l.trim().is_empty())
        .map(Json::parse)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": 1, "b": [1.5, true, null, "x\ny"], "c": {"d": -2e3}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.f64_of("a").unwrap(), 1.0);
        assert_eq!(v.req("b").unwrap().as_arr().unwrap().len(), 4);
        assert_eq!(v.req("c").unwrap().f64_of("d").unwrap(), -2000.0);
        let re = Json::parse(&v.dump()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""aéb😀c""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "aéb😀c");
    }

    #[test]
    fn utf8_passthrough() {
        let v = Json::parse("\"héllo — ‖ΔWx‖\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo — ‖ΔWx‖");
        assert_eq!(Json::parse(&v.dump()).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("01x").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn builder() {
        let mut o = Json::obj();
        o.set("x", 3usize).set("name", "dp-llm").set("ok", true);
        let s = o.dump();
        let back = Json::parse(&s).unwrap();
        assert_eq!(back.usize_of("x").unwrap(), 3);
        assert_eq!(back.str_of("name").unwrap(), "dp-llm");
    }

    #[test]
    fn nested_deep() {
        let mut s = String::new();
        for _ in 0..64 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..64 {
            s.push(']');
        }
        assert!(Json::parse(&s).is_ok());
    }
}
