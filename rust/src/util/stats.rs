//! Summary statistics + a micro-benchmark harness (criterion substitute).

use std::time::Instant;

/// Mean of a slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Geometric mean (paper reports geomeans of overheads).
pub fn geomean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    (xs.iter().map(|x| x.max(1e-300).ln()).sum::<f64>() / xs.len() as f64).exp()
}

/// Population standard deviation.
pub fn stddev(xs: &[f64]) -> f64 {
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// q-quantile with linear interpolation (q in [0,1]); matches numpy's
/// default 'linear' method, which Phase-3 threshold translation relies on.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Percentile helper (p in [0,100]).
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    quantile(xs, p / 100.0)
}

/// Nearest-rank percentile (p in [0,100]): the smallest element with at
/// least `⌈p/100·n⌉` observations at or below it — an actual observed
/// sample, never an interpolated value, which is what tail-latency
/// reporting wants (a p999 that was really measured).  Distinct from
/// [`quantile`]/[`percentile`], whose numpy-linear interpolation the
/// Phase-3 threshold translation depends on.  Returns `None` for empty
/// input or when any sample is NaN — a poisoned latency series must
/// fail loudly, not sort arbitrarily.
pub fn percentile_nearest_rank(xs: &[f64], p: f64) -> Option<f64> {
    if xs.is_empty() || xs.iter().any(|x| x.is_nan()) {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0 * v.len() as f64).ceil() as usize)
        .clamp(1, v.len());
    Some(v[rank - 1])
}

/// The tail summary every latency-reporting bench shares: nearest-rank
/// p50/p90/p99/p999 computed in one sort.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TailPercentiles {
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
}

/// One-sort [`percentile_nearest_rank`] at the standard report points.
/// Same `None` contract: empty or NaN-containing input.
pub fn tail_percentiles(xs: &[f64]) -> Option<TailPercentiles> {
    if xs.is_empty() || xs.iter().any(|x| x.is_nan()) {
        return None;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let at = |p: f64| {
        let rank = ((p / 100.0 * v.len() as f64).ceil() as usize)
            .clamp(1, v.len());
        v[rank - 1]
    };
    Some(TailPercentiles {
        p50: at(50.0),
        p90: at(90.0),
        p99: at(99.0),
        p999: at(99.9),
    })
}

/// Median absolute deviation — robust spread for bench reporting.
pub fn mad(xs: &[f64]) -> f64 {
    let med = quantile(xs, 0.5);
    let dev: Vec<f64> = xs.iter().map(|x| (x - med).abs()).collect();
    quantile(&dev, 0.5)
}

/// Ordinary least squares fit y ≈ a·x + b; returns (a, b, r²).
/// Used by the linear-regression relative-error estimator check on the
/// Rust side and by the device cost-model fitting.
pub fn linfit(x: &[f64], y: &[f64]) -> (f64, f64, f64) {
    assert_eq!(x.len(), y.len());
    let n = x.len() as f64;
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    let syy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum();
    if sxx == 0.0 || n < 2.0 {
        return (0.0, my, 0.0);
    }
    let a = sxy / sxx;
    let b = my - a * mx;
    let r2 = if syy == 0.0 { 1.0 } else { (sxy * sxy) / (sxx * syy) };
    (a, b, r2)
}

/// Timing sample from [`bench`].
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    /// median ns per iteration
    pub median_ns: f64,
    pub mad_ns: f64,
    pub samples: usize,
    pub iters_per_sample: usize,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<40} {:>12.0} ns/iter (±{:.0} MAD, {} samples × {} iters)",
            self.name, self.median_ns, self.mad_ns, self.samples, self.iters_per_sample
        )
    }
}

/// Micro-benchmark: warm up, auto-calibrate iterations per sample to
/// ~`target_sample_ms`, collect `samples` medians. criterion-lite.
pub fn bench(name: &str, samples: usize, target_sample_ms: f64, mut f: impl FnMut()) -> BenchResult {
    // Warmup + calibration.
    f();
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_secs_f64().max(1e-9);
    let iters = ((target_sample_ms / 1e3 / once).ceil() as usize).clamp(1, 1_000_000);
    let mut per_iter = Vec::with_capacity(samples);
    for _ in 0..samples {
        let t = Instant::now();
        for _ in 0..iters {
            f();
        }
        per_iter.push(t.elapsed().as_secs_f64() * 1e9 / iters as f64);
    }
    BenchResult {
        name: name.to_string(),
        median_ns: quantile(&per_iter, 0.5),
        mad_ns: mad(&per_iter),
        samples,
        iters_per_sample: iters,
    }
}

/// Render an aligned text table (the bench harness prints paper-style rows).
pub fn format_table(header: &[&str], rows: &[Vec<String>]) -> String {
    let ncol = header.len();
    let mut width: Vec<usize> = header.iter().map(|h| h.len()).collect();
    for r in rows {
        for (i, c) in r.iter().enumerate().take(ncol) {
            width[i] = width[i].max(c.len());
        }
    }
    let mut out = String::new();
    let line = |out: &mut String, cells: Vec<String>| {
        for (i, c) in cells.iter().enumerate().take(ncol) {
            if i > 0 {
                out.push_str("  ");
            }
            out.push_str(&format!("{:<w$}", c, w = width[i]));
        }
        out.push('\n');
    };
    line(&mut out, header.iter().map(|s| s.to_string()).collect());
    line(
        &mut out,
        width.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>(),
    );
    for r in rows {
        line(&mut out, r.clone());
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_matches_numpy_linear() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
        assert!((quantile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((quantile(&xs, 1.0) - 4.0).abs() < 1e-12);
    }

    /// Naive nearest-rank oracle: full sort, count-based rank walk.
    fn oracle_nearest_rank(xs: &[f64], p: f64) -> Option<f64> {
        if xs.is_empty() || xs.iter().any(|x| x.is_nan()) {
            return None;
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let need = (p / 100.0 * v.len() as f64).ceil().max(1.0) as usize;
        // Walk until `need` observations are at or below the candidate.
        for (i, x) in v.iter().enumerate() {
            if i + 1 >= need {
                return Some(*x);
            }
        }
        v.last().copied()
    }

    #[test]
    fn nearest_rank_empty_and_nan_rejected() {
        assert_eq!(percentile_nearest_rank(&[], 50.0), None);
        assert_eq!(percentile_nearest_rank(&[1.0, f64::NAN], 50.0), None);
        assert_eq!(tail_percentiles(&[]), None);
        assert_eq!(tail_percentiles(&[f64::NAN]), None);
    }

    #[test]
    fn nearest_rank_single_element_is_every_percentile() {
        for p in [0.0, 50.0, 99.0, 99.9, 100.0] {
            assert_eq!(percentile_nearest_rank(&[7.5], p), Some(7.5));
        }
        let t = tail_percentiles(&[7.5]).unwrap();
        assert_eq!((t.p50, t.p90, t.p99, t.p999), (7.5, 7.5, 7.5, 7.5));
    }

    #[test]
    fn nearest_rank_ties_and_known_values() {
        // All-ties: any percentile is the tied value.
        assert_eq!(percentile_nearest_rank(&[3.0; 10], 99.9), Some(3.0));
        // 1..=100: nearest-rank pK is exactly K.
        let xs: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        assert_eq!(percentile_nearest_rank(&xs, 50.0), Some(50.0));
        assert_eq!(percentile_nearest_rank(&xs, 99.0), Some(99.0));
        assert_eq!(percentile_nearest_rank(&xs, 99.9), Some(100.0));
    }

    /// Property: the one-sort implementation matches the naive oracle on
    /// random lengths/values (with duplicates), at every report point.
    #[test]
    fn nearest_rank_matches_oracle_property() {
        crate::util::rng::for_each_seed(25, |rng| {
            let n = rng.range(1, 400);
            // Coarse values force ties.
            let xs: Vec<f64> =
                (0..n).map(|_| rng.range(0, 50) as f64).collect();
            for p in [0.0, 10.0, 50.0, 90.0, 99.0, 99.9, 100.0] {
                assert_eq!(
                    percentile_nearest_rank(&xs, p),
                    oracle_nearest_rank(&xs, p),
                    "n={n} p={p}"
                );
            }
            let t = tail_percentiles(&xs).unwrap();
            assert_eq!(Some(t.p50), oracle_nearest_rank(&xs, 50.0));
            assert_eq!(Some(t.p90), oracle_nearest_rank(&xs, 90.0));
            assert_eq!(Some(t.p99), oracle_nearest_rank(&xs, 99.0));
            assert_eq!(Some(t.p999), oracle_nearest_rank(&xs, 99.9));
        });
    }

    #[test]
    fn linfit_exact_line() {
        let x: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().map(|v| 3.0 * v - 7.0).collect();
        let (a, b, r2) = linfit(&x, &y);
        assert!((a - 3.0).abs() < 1e-9);
        assert!((b + 7.0).abs() < 1e-9);
        assert!((r2 - 1.0).abs() < 1e-9);
    }

    #[test]
    fn linfit_no_correlation() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [5.0, 5.0, 5.0, 5.0];
        let (a, _b, r2) = linfit(&x, &y);
        assert_eq!(a, 0.0);
        assert!((r2 - 1.0).abs() < 1e-9); // flat y: syy == 0 treated as perfect fit
    }

    #[test]
    fn geomean_basic() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn table_formatting() {
        let t = format_table(
            &["a", "bbbb"],
            &[vec!["x".into(), "1".into()], vec!["yyyy".into(), "2".into()]],
        );
        assert!(t.contains("a     bbbb"));
    }
}
