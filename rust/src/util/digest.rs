//! CRC-32 (IEEE, reflected) — the integrity digest of the DPAK container
//! and the member checksum of the hand-rolled zip writer in
//! [`crate::util::npz`].
//!
//! Chosen over a cryptographic hash deliberately: the threat model is
//! *corruption* (truncated copies, flipped bits on disk or in transit),
//! not adversaries, and CRC-32 detects every single-bit error and every
//! burst ≤ 32 bits.  The same polynomial is available as `zlib.crc32` on
//! the Python side, so `python/compile/pack.py` and the Rust loader agree
//! byte-for-byte without either side shipping a hash dependency.

/// Table-driven CRC-32 with the reflected IEEE polynomial 0xEDB88320
/// (the zlib/zip/PNG CRC).
const fn build_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = build_table();

/// Streaming CRC-32 state (for digesting large sections chunk-wise).
#[derive(Debug, Clone, Copy)]
pub struct Crc32(u32);

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32(0xFFFF_FFFF)
    }

    pub fn update(&mut self, bytes: &[u8]) {
        let mut c = self.0;
        for &b in bytes {
            c = TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.0 = c;
    }

    pub fn finish(self) -> u32 {
        self.0 ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Crc32 {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(bytes);
    c.finish()
}

/// The digest string format used in DPAK manifests: `crc32:xxxxxxxx`.
pub fn digest_str(bytes: &[u8]) -> String {
    format!("crc32:{:08x}", crc32(bytes))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Known vectors — the same values `zlib.crc32` produces, pinning the
    /// cross-language contract with `python/compile/pack.py`.
    #[test]
    fn known_vectors() {
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"),
                   0x414F_A339);
        assert_eq!(digest_str(b"123456789"), "crc32:cbf43926");
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..=255u8).cycle().take(10_000).collect();
        let mut c = Crc32::new();
        for chunk in data.chunks(97) {
            c.update(chunk);
        }
        assert_eq!(c.finish(), crc32(&data));
    }

    #[test]
    fn single_bit_flip_detected() {
        let data: Vec<u8> = (0..1024u32).map(|i| (i * 7) as u8).collect();
        let base = crc32(&data);
        for byte in [0usize, 511, 1023] {
            for bit in 0..8 {
                let mut flipped = data.clone();
                flipped[byte] ^= 1 << bit;
                assert_ne!(crc32(&flipped), base, "byte {byte} bit {bit}");
            }
        }
    }
}
