//! Support utilities hand-rolled for the offline sandbox (no serde / clap /
//! criterion in the crate cache): JSON, npz/npy, CLI parsing, stats, PRNG,
//! a micro-bench harness and a tiny logger.

pub mod cli;
pub mod digest;
pub mod json;
pub mod mmap;
pub mod npz;
pub mod rng;
pub mod stats;

pub use json::Json;
