//! npz / npy reading and writing for artifact tensors.
//!
//! The Python build pipeline stores checkpoints / quantized weights /
//! estimator stacks as **uncompressed** `.npz` (a zip of stored `.npy`
//! members — `np.savez`, not `savez_compressed`; `io_utils.save_npz`
//! pins this).  This module parses the npy header dialect numpy actually
//! emits (v1.0/2.0, C-order) for the dtypes the pipeline uses: f32, f64,
//! i64, i32, u16, u8, bool — and reads/writes the zip container itself
//! with a minimal stored-only (method 0) implementation, so the crate
//! carries no zip dependency.
//!
//! Malformed archives fail with a typed [`NpzError`] naming the member
//! and the reason (unsupported compression method, truncated data, bad
//! container structure) instead of a generic parse failure — fleet boot
//! surfaces *which* artifact is bad and why.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::digest::crc32;

/// A loaded array: shape + flat data in one of the supported dtypes.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

#[derive(Debug, Clone)]
pub enum NpyData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I64(Vec<i64>),
    I32(Vec<i32>),
    U16(Vec<u16>),
    U8(Vec<u8>),
    Bool(Vec<bool>),
}

impl NpyArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Convert to f32 regardless of stored dtype (lossy for i64/f64).
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.data {
            NpyData::F32(v) => v.clone(),
            NpyData::F64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::U16(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::Bool(v) => v.iter().map(|&x| x as u8 as f32).collect(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            NpyData::F32(v) => Ok(v),
            other => bail!("expected f32 array, got {:?}", dtype_name(other)),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            NpyData::U8(v) => Ok(v),
            other => bail!("expected u8 array, got {:?}", dtype_name(other)),
        }
    }

    pub fn as_u16(&self) -> Result<&[u16]> {
        match &self.data {
            NpyData::U16(v) => Ok(v),
            other => bail!("expected u16 array, got {:?}", dtype_name(other)),
        }
    }

    pub fn to_i64(&self) -> Vec<i64> {
        match &self.data {
            NpyData::I64(v) => v.clone(),
            NpyData::I32(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::U16(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::U8(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::F64(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::F32(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::Bool(v) => v.iter().map(|&x| x as i64).collect(),
        }
    }
}

fn dtype_name(d: &NpyData) -> &'static str {
    match d {
        NpyData::F32(_) => "f32",
        NpyData::F64(_) => "f64",
        NpyData::I64(_) => "i64",
        NpyData::I32(_) => "i32",
        NpyData::U16(_) => "u16",
        NpyData::U8(_) => "u8",
        NpyData::Bool(_) => "bool",
    }
}

/// Parse a `.npy` byte buffer.
pub fn parse_npy(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let major = bytes[6];
    let (header_len, data_off) = match major {
        1 => {
            let n = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
            (n, 10 + n)
        }
        2 | 3 => {
            let n = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
            (n, 12 + n)
        }
        v => bail!("unsupported npy version {v}"),
    };
    let header = std::str::from_utf8(&bytes[data_off - header_len..data_off])
        .context("npy header not utf-8")?;
    let descr = dict_field(header, "descr")?;
    let fortran = dict_field(header, "fortran_order")?;
    if fortran.trim() != "False" {
        bail!("fortran-order npy not supported");
    }
    let shape_s = dict_field(header, "shape")?;
    let shape: Vec<usize> = shape_s
        .trim()
        .trim_start_matches('(')
        .trim_end_matches(')')
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().map_err(|e| anyhow!("bad shape '{t}': {e}")))
        .collect::<Result<_>>()?;
    let n: usize = shape.iter().product();
    let raw = &bytes[data_off..];
    let descr = descr.trim().trim_matches(|c| c == '\'' || c == '"');
    let data = match descr {
        "<f4" => NpyData::F32(read_le::<4, f32>(raw, n, f32::from_le_bytes)?),
        "<f8" => NpyData::F64(read_le::<8, f64>(raw, n, f64::from_le_bytes)?),
        "<i8" => NpyData::I64(read_le::<8, i64>(raw, n, i64::from_le_bytes)?),
        "<i4" => NpyData::I32(read_le::<4, i32>(raw, n, i32::from_le_bytes)?),
        "<u2" => NpyData::U16(read_le::<2, u16>(raw, n, u16::from_le_bytes)?),
        "|u1" | "<u1" => NpyData::U8(raw.get(..n).ok_or_else(|| anyhow!("short npy"))?.to_vec()),
        "|b1" => NpyData::Bool(
            raw.get(..n)
                .ok_or_else(|| anyhow!("short npy"))?
                .iter()
                .map(|&b| b != 0)
                .collect(),
        ),
        d => bail!("unsupported npy dtype '{d}'"),
    };
    Ok(NpyArray { shape, data })
}

fn read_le<const W: usize, T>(raw: &[u8], n: usize, f: fn([u8; W]) -> T) -> Result<Vec<T>> {
    if raw.len() < n * W {
        bail!("npy data too short: want {} bytes, have {}", n * W, raw.len());
    }
    Ok(raw[..n * W]
        .chunks_exact(W)
        .map(|c| f(c.try_into().unwrap()))
        .collect())
}

/// Pull `'key': value` out of the python-dict-literal npy header.
fn dict_field<'a>(header: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("'{key}':");
    let at = header
        .find(&pat)
        .ok_or_else(|| anyhow!("npy header missing '{key}'"))?;
    let rest = &header[at + pat.len()..];
    // Value ends at the next top-level ',' (parens may nest for shape).
    let mut depth = 0usize;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => {
                if depth == 0 {
                    return Ok(&rest[..i]);
                }
                depth -= 1;
            }
            ',' if depth == 0 => return Ok(&rest[..i]),
            '}' if depth == 0 => return Ok(&rest[..i]),
            _ => {}
        }
    }
    Ok(rest)
}

/// Why an `.npz` container could not be read.  Carried inside the
/// `anyhow` chain so callers (and tests) can `downcast_ref::<NpzError>()`
/// to branch on the exact failure.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum NpzError {
    /// No end-of-central-directory record — not a zip file at all.
    NotZip,
    /// A member is stored with a compression method this reader does not
    /// implement (the pipeline writes method 0 / stored only).
    UnsupportedCompression { member: String, method: u16 },
    /// A member's data runs past the end of the file.
    TruncatedMember { member: String, need: usize, have: usize },
    /// The container structure itself is cut short or inconsistent
    /// (central directory / local header out of bounds, bad signature).
    BadContainer { detail: String },
}

impl fmt::Display for NpzError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            NpzError::NotZip => {
                write!(f, "not a zip archive (no end-of-central-directory record)")
            }
            NpzError::UnsupportedCompression { member, method } => {
                let name = match method {
                    8 => " (deflate)",
                    12 => " (bzip2)",
                    14 => " (lzma)",
                    93 => " (zstd)",
                    _ => "",
                };
                write!(
                    f,
                    "member '{member}': unsupported zip compression method \
                     {method}{name} — the pipeline writes stored (method 0) \
                     npz; re-save without compression"
                )
            }
            NpzError::TruncatedMember { member, need, have } => {
                write!(f, "member '{member}': truncated — wants {need} data bytes, \
                           file has {have} past its header")
            }
            NpzError::BadContainer { detail } => {
                write!(f, "corrupt zip container: {detail}")
            }
        }
    }
}

impl std::error::Error for NpzError {}

fn u16_at(bytes: &[u8], off: usize) -> u16 {
    u16::from_le_bytes([bytes[off], bytes[off + 1]])
}

fn u32_at(bytes: &[u8], off: usize) -> u32 {
    u32::from_le_bytes([bytes[off], bytes[off + 1], bytes[off + 2], bytes[off + 3]])
}

const EOCD_SIG: u32 = 0x0605_4B50;
const CDIR_SIG: u32 = 0x0201_4B50;
const LOCAL_SIG: u32 = 0x0403_4B50;

/// Parse a stored-only zip archive into `(member name, data range)` pairs.
/// Central-directory sizes are authoritative (local headers may defer
/// sizes to a data descriptor, which numpy's writer uses when streaming).
fn zip_members(bytes: &[u8]) -> Result<Vec<(String, std::ops::Range<usize>)>, NpzError> {
    // EOCD: scan backwards over the (≤ 64 KiB) comment space.
    if bytes.len() < 22 {
        return Err(NpzError::NotZip);
    }
    let scan_from = bytes.len().saturating_sub(22 + 0xFFFF);
    let mut eocd = None;
    for off in (scan_from..=bytes.len() - 22).rev() {
        if u32_at(bytes, off) == EOCD_SIG {
            eocd = Some(off);
            break;
        }
    }
    let eocd = eocd.ok_or(NpzError::NotZip)?;
    let n_entries = u16_at(bytes, eocd + 10) as usize;
    let cd_size = u32_at(bytes, eocd + 12) as usize;
    let cd_off = u32_at(bytes, eocd + 16) as usize;
    if n_entries == 0xFFFF || cd_off == 0xFFFF_FFFF {
        return Err(NpzError::BadContainer { detail: "zip64 archives not supported".into() });
    }
    if cd_off.checked_add(cd_size).map(|end| end > bytes.len()).unwrap_or(true) {
        return Err(NpzError::BadContainer {
            detail: format!(
                "central directory [{cd_off}, +{cd_size}) past end of file ({})",
                bytes.len()
            ),
        });
    }
    let mut members = Vec::with_capacity(n_entries);
    let mut off = cd_off;
    for i in 0..n_entries {
        if off + 46 > cd_off + cd_size || u32_at(bytes, off) != CDIR_SIG {
            return Err(NpzError::BadContainer {
                detail: format!("central directory entry {i} truncated or bad signature"),
            });
        }
        let method = u16_at(bytes, off + 10);
        let comp_size = u32_at(bytes, off + 20) as usize;
        let uncomp_size = u32_at(bytes, off + 24) as usize;
        let name_len = u16_at(bytes, off + 28) as usize;
        let extra_len = u16_at(bytes, off + 30) as usize;
        let comment_len = u16_at(bytes, off + 32) as usize;
        let local_off = u32_at(bytes, off + 42) as usize;
        if off + 46 + name_len > bytes.len() {
            return Err(NpzError::BadContainer {
                detail: format!("member name of entry {i} runs past end of file"),
            });
        }
        let name = String::from_utf8_lossy(&bytes[off + 46..off + 46 + name_len]).into_owned();
        if method != 0 {
            return Err(NpzError::UnsupportedCompression { member: name, method });
        }
        if comp_size != uncomp_size {
            return Err(NpzError::BadContainer {
                detail: format!(
                    "member '{name}': stored sizes disagree ({comp_size} != {uncomp_size})"
                ),
            });
        }
        // Local header gives the actual data offset (its name/extra
        // fields may differ in length from the central directory's).
        if local_off + 30 > bytes.len() || u32_at(bytes, local_off) != LOCAL_SIG {
            return Err(NpzError::BadContainer {
                detail: format!("member '{name}': local header at {local_off} invalid"),
            });
        }
        let l_name = u16_at(bytes, local_off + 26) as usize;
        let l_extra = u16_at(bytes, local_off + 28) as usize;
        let data_off = local_off + 30 + l_name + l_extra;
        if data_off + comp_size > bytes.len() {
            return Err(NpzError::TruncatedMember {
                member: name,
                need: comp_size,
                have: bytes.len().saturating_sub(data_off),
            });
        }
        members.push((name, data_off..data_off + comp_size));
        off += 46 + name_len + extra_len + comment_len;
    }
    Ok(members)
}

/// Read every member of an `.npz` (zip of `.npy`) file.
pub fn load_npz(path: &str) -> Result<BTreeMap<String, NpyArray>> {
    let bytes = std::fs::read(path).with_context(|| format!("opening {path}"))?;
    let members = zip_members(&bytes)
        .map_err(|e| anyhow!(e).context(format!("reading zip {path}")))?;
    let mut out = BTreeMap::new();
    for (full_name, range) in members {
        let name = full_name.trim_end_matches(".npy").to_string();
        let arr = parse_npy(&bytes[range])
            .with_context(|| format!("member '{name}' of {path}"))?;
        out.insert(name, arr);
    }
    Ok(out)
}

/// Serialize one array into `.npy` bytes (the dtypes the pipeline packs).
pub fn npy_bytes(shape: &[usize], data: &NpyData) -> Vec<u8> {
    let (descr, payload): (&str, Vec<u8>) = match data {
        NpyData::U8(v) => ("|u1", v.clone()),
        NpyData::F32(v) => {
            ("<f4", v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        NpyData::F64(v) => {
            ("<f8", v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        NpyData::I64(v) => {
            ("<i8", v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        NpyData::I32(v) => {
            ("<i4", v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        NpyData::U16(v) => {
            ("<u2", v.iter().flat_map(|x| x.to_le_bytes()).collect())
        }
        NpyData::Bool(v) => ("|b1", v.iter().map(|&b| b as u8).collect()),
    };
    let shape_s = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header =
        format!("{{'descr': '{descr}', 'fortran_order': False, 'shape': {shape_s}, }}");
    let total = 10 + header.len() + 1;
    let pad = (64 - total % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut bytes = Vec::with_capacity(10 + header.len() + payload.len());
    bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
    bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    bytes.extend_from_slice(&payload);
    bytes
}

/// Write a stored-only (method 0) `.npz`, byte-compatible with what
/// `np.savez` emits — names gain the `.npy` suffix numpy uses.  Used by
/// the differential round-trip tests and the cold-start bench to build
/// legacy-path stores without Python in the loop.
pub fn write_npz(path: &str, members: &[(&str, &[usize], NpyData)]) -> Result<()> {
    let mut out: Vec<u8> = Vec::new();
    let mut central: Vec<u8> = Vec::new();
    let mut n = 0u16;
    for (name, shape, data) in members {
        let payload = npy_bytes(shape, data);
        let full = format!("{name}.npy");
        let crc = crc32(&payload);
        let local_off = out.len() as u32;
        let sz = payload.len() as u32;
        // Local header: stored, no flags, zeroed DOS time.
        out.extend_from_slice(&LOCAL_SIG.to_le_bytes());
        out.extend_from_slice(&20u16.to_le_bytes()); // version needed
        out.extend_from_slice(&0u16.to_le_bytes()); // flags
        out.extend_from_slice(&0u16.to_le_bytes()); // method 0 = stored
        out.extend_from_slice(&0u32.to_le_bytes()); // mod time+date
        out.extend_from_slice(&crc.to_le_bytes());
        out.extend_from_slice(&sz.to_le_bytes()); // compressed
        out.extend_from_slice(&sz.to_le_bytes()); // uncompressed
        out.extend_from_slice(&(full.len() as u16).to_le_bytes());
        out.extend_from_slice(&0u16.to_le_bytes()); // extra len
        out.extend_from_slice(full.as_bytes());
        out.extend_from_slice(&payload);
        // Central directory entry.
        central.extend_from_slice(&CDIR_SIG.to_le_bytes());
        central.extend_from_slice(&20u16.to_le_bytes()); // version made by
        central.extend_from_slice(&20u16.to_le_bytes()); // version needed
        central.extend_from_slice(&0u16.to_le_bytes()); // flags
        central.extend_from_slice(&0u16.to_le_bytes()); // method
        central.extend_from_slice(&0u32.to_le_bytes()); // mod time+date
        central.extend_from_slice(&crc.to_le_bytes());
        central.extend_from_slice(&sz.to_le_bytes());
        central.extend_from_slice(&sz.to_le_bytes());
        central.extend_from_slice(&(full.len() as u16).to_le_bytes());
        central.extend_from_slice(&0u16.to_le_bytes()); // extra len
        central.extend_from_slice(&0u16.to_le_bytes()); // comment len
        central.extend_from_slice(&0u16.to_le_bytes()); // disk number
        central.extend_from_slice(&0u16.to_le_bytes()); // internal attrs
        central.extend_from_slice(&0u32.to_le_bytes()); // external attrs
        central.extend_from_slice(&local_off.to_le_bytes());
        central.extend_from_slice(full.as_bytes());
        n += 1;
    }
    let cd_off = out.len() as u32;
    let cd_size = central.len() as u32;
    out.extend_from_slice(&central);
    out.extend_from_slice(&EOCD_SIG.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes()); // disk number
    out.extend_from_slice(&0u16.to_le_bytes()); // cd start disk
    out.extend_from_slice(&n.to_le_bytes()); // entries on disk
    out.extend_from_slice(&n.to_le_bytes()); // entries total
    out.extend_from_slice(&cd_size.to_le_bytes());
    out.extend_from_slice(&cd_off.to_le_bytes());
    out.extend_from_slice(&0u16.to_le_bytes()); // comment len
    std::fs::write(path, out).with_context(|| format!("writing {path}"))
}

/// Write a single f32 `.npy` file (used by tests and debug dumps).
pub fn write_npy_f32(path: &str, shape: &[usize], data: &[f32]) -> Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_s = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_s}, }}"
    );
    let total = 10 + header.len() + 1;
    let pad = (64 - total % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut bytes = Vec::with_capacity(10 + header.len() + data.len() * 4);
    bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
    bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {path}"))
}

/// Load a raw little-endian uint16 token stream (`.bin` files from dataprep).
pub fn load_u16_bin(path: &str) -> Result<Vec<u16>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    if bytes.len() % 2 != 0 {
        bail!("{path}: odd byte count for u16 stream");
    }
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npy_roundtrip() {
        let tmp = std::env::temp_dir().join("dpllm_npz_test.npy");
        let path = tmp.to_str().unwrap();
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        write_npy_f32(path, &[2, 3, 4], &data).unwrap();
        let arr = parse_npy(&std::fs::read(path).unwrap()).unwrap();
        assert_eq!(arr.shape, vec![2, 3, 4]);
        assert_eq!(arr.as_f32().unwrap(), &data[..]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn npy_scalar_and_1d() {
        let tmp = std::env::temp_dir().join("dpllm_npz_test2.npy");
        let path = tmp.to_str().unwrap();
        write_npy_f32(path, &[5], &[1., 2., 3., 4., 5.]).unwrap();
        let arr = parse_npy(&std::fs::read(path).unwrap()).unwrap();
        assert_eq!(arr.shape, vec![5]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_npy() {
        assert!(parse_npy(b"hello world, not npy").is_err());
    }

    fn tmp(name: &str) -> String {
        std::env::temp_dir().join(name).to_string_lossy().into_owned()
    }

    fn sample_npz(path: &str) {
        let planes: Vec<u8> = (0..48u32).map(|i| (i * 3) as u8).collect();
        let lut: Vec<f32> = (0..16).map(|i| i as f32 * 0.25).collect();
        write_npz(path, &[
            ("planes_wq", &[2, 24][..], NpyData::U8(planes)),
            ("lut3_wq", &[2, 8][..], NpyData::F32(lut)),
        ])
        .unwrap();
    }

    #[test]
    fn npz_roundtrip_stored_zip() {
        let path = tmp("dpllm_npz_rt.npz");
        sample_npz(&path);
        let arrays = load_npz(&path).unwrap();
        assert_eq!(arrays.len(), 2);
        let p = &arrays["planes_wq"];
        assert_eq!(p.shape, vec![2, 24]);
        assert_eq!(p.as_u8().unwrap()[47], (47 * 3u32) as u8);
        let l = &arrays["lut3_wq"];
        assert_eq!(l.as_f32().unwrap()[15], 3.75);
        std::fs::remove_file(&path).ok();
    }

    fn npz_error_of(path: &str) -> NpzError {
        let err = load_npz(path).unwrap_err();
        err.downcast_ref::<NpzError>()
            .unwrap_or_else(|| panic!("expected NpzError, got: {err:#}"))
            .clone()
    }

    /// A deflated member must name the member and the method — not fail
    /// with a generic parse error.  Hand-built single-member archive with
    /// method 8 in both headers.
    #[test]
    fn typed_error_on_compressed_member() {
        let path = tmp("dpllm_npz_deflate.npz");
        sample_npz(&path);
        let mut bytes = std::fs::read(&path).unwrap();
        // Patch method fields (offset 8 in local header at 0; offset 10 in
        // the first central entry) from 0 to 8.
        let cd = bytes.len() - 22;
        let cd_off = u32_at(&bytes, cd + 16) as usize;
        bytes[8] = 8; // local header method (first member starts at 0)
        bytes[cd_off + 10] = 8; // central directory method
        std::fs::write(&path, &bytes).unwrap();
        match npz_error_of(&path) {
            NpzError::UnsupportedCompression { member, method: 8 } => {
                assert_eq!(member, "planes_wq.npy");
            }
            other => panic!("wrong error: {other}"),
        }
        std::fs::remove_file(&path).ok();
    }

    /// Truncating a member's data (while keeping the central directory
    /// intact) must report the member and the byte shortfall.
    #[test]
    fn typed_error_on_truncated_member() {
        let path = tmp("dpllm_npz_trunc.npz");
        // Archive with the big member LAST so cutting its tail leaves the
        // EOCD findable — emulate by rebuilding: write full file, then
        // splice out bytes from the middle of the last member's data and
        // shrink nothing else.  Simplest robust corruption: lie in the
        // central directory that the member is bigger than the file.
        sample_npz(&path);
        let mut bytes = std::fs::read(&path).unwrap();
        let cd = bytes.len() - 22;
        let cd_off = u32_at(&bytes, cd + 16) as usize;
        // Inflate the first member's sizes to 16 MiB in the central dir.
        let huge = (16u32 << 20).to_le_bytes();
        bytes[cd_off + 20..cd_off + 24].copy_from_slice(&huge);
        bytes[cd_off + 24..cd_off + 28].copy_from_slice(&huge);
        std::fs::write(&path, &bytes).unwrap();
        match npz_error_of(&path) {
            NpzError::TruncatedMember { member, need, have } => {
                assert_eq!(member, "planes_wq.npy");
                assert_eq!(need, 16 << 20);
                assert!(have < need);
            }
            other => panic!("wrong error: {other}"),
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn typed_error_on_non_zip() {
        let path = tmp("dpllm_npz_notzip.npz");
        std::fs::write(&path, b"definitely not a zip archive").unwrap();
        assert_eq!(npz_error_of(&path), NpzError::NotZip);
        std::fs::remove_file(&path).ok();
    }

    /// Chopping the file mid-central-directory is a container-level error
    /// (the EOCD points past the end).
    #[test]
    fn typed_error_on_truncated_container() {
        let path = tmp("dpllm_npz_cut.npz");
        sample_npz(&path);
        let bytes = std::fs::read(&path).unwrap();
        let cd = bytes.len() - 22;
        // Keep the EOCD but drop 8 bytes of central directory before it.
        let mut cut = bytes[..cd - 8].to_vec();
        cut.extend_from_slice(&bytes[cd..]);
        std::fs::write(&path, &cut).unwrap();
        match npz_error_of(&path) {
            NpzError::BadContainer { .. } => {}
            other => panic!("wrong error: {other}"),
        }
        std::fs::remove_file(&path).ok();
    }
}
