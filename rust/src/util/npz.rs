//! npz / npy reading (and npy writing) for artifact tensors.
//!
//! The Python build pipeline stores checkpoints / quantized weights /
//! estimator stacks as uncompressed-or-deflated `.npz` (a zip of `.npy`
//! members).  This module parses the npy header dialect numpy actually
//! emits (v1.0/2.0, C-order) for the dtypes the pipeline uses: f32, f64,
//! i64, i32, u16, u8, bool.

use std::collections::BTreeMap;
use std::io::Read;

use anyhow::{anyhow, bail, Context, Result};

/// A loaded array: shape + flat data in one of the supported dtypes.
#[derive(Debug, Clone)]
pub struct NpyArray {
    pub shape: Vec<usize>,
    pub data: NpyData,
}

#[derive(Debug, Clone)]
pub enum NpyData {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I64(Vec<i64>),
    I32(Vec<i32>),
    U16(Vec<u16>),
    U8(Vec<u8>),
    Bool(Vec<bool>),
}

impl NpyArray {
    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Convert to f32 regardless of stored dtype (lossy for i64/f64).
    pub fn to_f32(&self) -> Vec<f32> {
        match &self.data {
            NpyData::F32(v) => v.clone(),
            NpyData::F64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I64(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::I32(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::U16(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::U8(v) => v.iter().map(|&x| x as f32).collect(),
            NpyData::Bool(v) => v.iter().map(|&x| x as u8 as f32).collect(),
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            NpyData::F32(v) => Ok(v),
            other => bail!("expected f32 array, got {:?}", dtype_name(other)),
        }
    }

    pub fn as_u8(&self) -> Result<&[u8]> {
        match &self.data {
            NpyData::U8(v) => Ok(v),
            other => bail!("expected u8 array, got {:?}", dtype_name(other)),
        }
    }

    pub fn as_u16(&self) -> Result<&[u16]> {
        match &self.data {
            NpyData::U16(v) => Ok(v),
            other => bail!("expected u16 array, got {:?}", dtype_name(other)),
        }
    }

    pub fn to_i64(&self) -> Vec<i64> {
        match &self.data {
            NpyData::I64(v) => v.clone(),
            NpyData::I32(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::U16(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::U8(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::F64(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::F32(v) => v.iter().map(|&x| x as i64).collect(),
            NpyData::Bool(v) => v.iter().map(|&x| x as i64).collect(),
        }
    }
}

fn dtype_name(d: &NpyData) -> &'static str {
    match d {
        NpyData::F32(_) => "f32",
        NpyData::F64(_) => "f64",
        NpyData::I64(_) => "i64",
        NpyData::I32(_) => "i32",
        NpyData::U16(_) => "u16",
        NpyData::U8(_) => "u8",
        NpyData::Bool(_) => "bool",
    }
}

/// Parse a `.npy` byte buffer.
pub fn parse_npy(bytes: &[u8]) -> Result<NpyArray> {
    if bytes.len() < 10 || &bytes[..6] != b"\x93NUMPY" {
        bail!("not an npy file");
    }
    let major = bytes[6];
    let (header_len, data_off) = match major {
        1 => {
            let n = u16::from_le_bytes([bytes[8], bytes[9]]) as usize;
            (n, 10 + n)
        }
        2 | 3 => {
            let n = u32::from_le_bytes([bytes[8], bytes[9], bytes[10], bytes[11]]) as usize;
            (n, 12 + n)
        }
        v => bail!("unsupported npy version {v}"),
    };
    let header = std::str::from_utf8(&bytes[data_off - header_len..data_off])
        .context("npy header not utf-8")?;
    let descr = dict_field(header, "descr")?;
    let fortran = dict_field(header, "fortran_order")?;
    if fortran.trim() != "False" {
        bail!("fortran-order npy not supported");
    }
    let shape_s = dict_field(header, "shape")?;
    let shape: Vec<usize> = shape_s
        .trim()
        .trim_start_matches('(')
        .trim_end_matches(')')
        .split(',')
        .map(|t| t.trim())
        .filter(|t| !t.is_empty())
        .map(|t| t.parse::<usize>().map_err(|e| anyhow!("bad shape '{t}': {e}")))
        .collect::<Result<_>>()?;
    let n: usize = shape.iter().product();
    let raw = &bytes[data_off..];
    let descr = descr.trim().trim_matches(|c| c == '\'' || c == '"');
    let data = match descr {
        "<f4" => NpyData::F32(read_le::<4, f32>(raw, n, f32::from_le_bytes)?),
        "<f8" => NpyData::F64(read_le::<8, f64>(raw, n, f64::from_le_bytes)?),
        "<i8" => NpyData::I64(read_le::<8, i64>(raw, n, i64::from_le_bytes)?),
        "<i4" => NpyData::I32(read_le::<4, i32>(raw, n, i32::from_le_bytes)?),
        "<u2" => NpyData::U16(read_le::<2, u16>(raw, n, u16::from_le_bytes)?),
        "|u1" | "<u1" => NpyData::U8(raw.get(..n).ok_or_else(|| anyhow!("short npy"))?.to_vec()),
        "|b1" => NpyData::Bool(
            raw.get(..n)
                .ok_or_else(|| anyhow!("short npy"))?
                .iter()
                .map(|&b| b != 0)
                .collect(),
        ),
        d => bail!("unsupported npy dtype '{d}'"),
    };
    Ok(NpyArray { shape, data })
}

fn read_le<const W: usize, T>(raw: &[u8], n: usize, f: fn([u8; W]) -> T) -> Result<Vec<T>> {
    if raw.len() < n * W {
        bail!("npy data too short: want {} bytes, have {}", n * W, raw.len());
    }
    Ok(raw[..n * W]
        .chunks_exact(W)
        .map(|c| f(c.try_into().unwrap()))
        .collect())
}

/// Pull `'key': value` out of the python-dict-literal npy header.
fn dict_field<'a>(header: &'a str, key: &str) -> Result<&'a str> {
    let pat = format!("'{key}':");
    let at = header
        .find(&pat)
        .ok_or_else(|| anyhow!("npy header missing '{key}'"))?;
    let rest = &header[at + pat.len()..];
    // Value ends at the next top-level ',' (parens may nest for shape).
    let mut depth = 0usize;
    for (i, c) in rest.char_indices() {
        match c {
            '(' | '[' => depth += 1,
            ')' | ']' => {
                if depth == 0 {
                    return Ok(&rest[..i]);
                }
                depth -= 1;
            }
            ',' if depth == 0 => return Ok(&rest[..i]),
            '}' if depth == 0 => return Ok(&rest[..i]),
            _ => {}
        }
    }
    Ok(rest)
}

/// Read every member of an `.npz` (zip) file.
pub fn load_npz(path: &str) -> Result<BTreeMap<String, NpyArray>> {
    let f = std::fs::File::open(path).with_context(|| format!("opening {path}"))?;
    let mut zip = zip::ZipArchive::new(f).with_context(|| format!("reading zip {path}"))?;
    let mut out = BTreeMap::new();
    for i in 0..zip.len() {
        let mut member = zip.by_index(i)?;
        let name = member
            .name()
            .trim_end_matches(".npy")
            .to_string();
        let mut buf = Vec::with_capacity(member.size() as usize);
        member.read_to_end(&mut buf)?;
        let arr = parse_npy(&buf).with_context(|| format!("member '{name}' of {path}"))?;
        out.insert(name, arr);
    }
    Ok(out)
}

/// Write a single f32 `.npy` file (used by tests and debug dumps).
pub fn write_npy_f32(path: &str, shape: &[usize], data: &[f32]) -> Result<()> {
    assert_eq!(shape.iter().product::<usize>(), data.len());
    let shape_s = match shape.len() {
        0 => "()".to_string(),
        1 => format!("({},)", shape[0]),
        _ => format!(
            "({})",
            shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(", ")
        ),
    };
    let mut header = format!(
        "{{'descr': '<f4', 'fortran_order': False, 'shape': {shape_s}, }}"
    );
    let total = 10 + header.len() + 1;
    let pad = (64 - total % 64) % 64;
    header.push_str(&" ".repeat(pad));
    header.push('\n');
    let mut bytes = Vec::with_capacity(10 + header.len() + data.len() * 4);
    bytes.extend_from_slice(b"\x93NUMPY\x01\x00");
    bytes.extend_from_slice(&(header.len() as u16).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    for x in data {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    std::fs::write(path, bytes).with_context(|| format!("writing {path}"))
}

/// Load a raw little-endian uint16 token stream (`.bin` files from dataprep).
pub fn load_u16_bin(path: &str) -> Result<Vec<u16>> {
    let bytes = std::fs::read(path).with_context(|| format!("reading {path}"))?;
    if bytes.len() % 2 != 0 {
        bail!("{path}: odd byte count for u16 stream");
    }
    Ok(bytes
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes([c[0], c[1]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn npy_roundtrip() {
        let tmp = std::env::temp_dir().join("dpllm_npz_test.npy");
        let path = tmp.to_str().unwrap();
        let data: Vec<f32> = (0..24).map(|i| i as f32 * 0.5).collect();
        write_npy_f32(path, &[2, 3, 4], &data).unwrap();
        let arr = parse_npy(&std::fs::read(path).unwrap()).unwrap();
        assert_eq!(arr.shape, vec![2, 3, 4]);
        assert_eq!(arr.as_f32().unwrap(), &data[..]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn npy_scalar_and_1d() {
        let tmp = std::env::temp_dir().join("dpllm_npz_test2.npy");
        let path = tmp.to_str().unwrap();
        write_npy_f32(path, &[5], &[1., 2., 3., 4., 5.]).unwrap();
        let arr = parse_npy(&std::fs::read(path).unwrap()).unwrap();
        assert_eq!(arr.shape, vec![5]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_non_npy() {
        assert!(parse_npy(b"hello world, not npy").is_err());
    }
}
