//! Tiny argv parser (clap is not in the offline crate cache).
//! Supports `--flag value`, `--flag=value`, bare `--switch`, positionals.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub flags: BTreeMap<String, String>,
    pub switches: Vec<String>,
    pub positional: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(name) = a.strip_prefix("--") {
                if let Some((k, v)) = name.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.flags.insert(name.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn req(&self, key: &str) -> Result<&str> {
        self.get(key).ok_or_else(|| anyhow!("missing required --{key}"))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_flags_and_switches() {
        // NOTE: a bare switch followed by a non-dash token is read as a
        // flag+value pair (documented parser behavior) — switches go last.
        let a = Args::parse(&sv(&["pos1", "--model", "dpl-tiny",
                                  "--target=4.0", "--verbose"]));
        assert_eq!(a.get("model"), Some("dpl-tiny"));
        assert_eq!(a.f64_or("target", 0.0), 4.0);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["pos1"]);
    }

    #[test]
    fn missing_required_errors() {
        let a = Args::parse(&sv(&[]));
        assert!(a.req("model").is_err());
        assert_eq!(a.usize_or("n", 7), 7);
    }
}
