//! Read-only memory-mapped files for zero-copy artifact loading.
//!
//! The DPAK loader ([`crate::anyprec::dpak`]) maps the container once and
//! hands every plane/LUT section out as a borrowed range of the mapping,
//! so N replicas share one physical copy of the weight store
//! (`Arc<Mmap>` refcount == number of live views).  The wrapper is
//! deliberately minimal: read-only, whole-file, private mapping — no
//! write-back, no partial maps, no unsafe surface beyond construction.
//!
//! On non-Unix targets (no `mmap(2)`) the same type degrades to an owned
//! read of the file: callers still share one buffer via the `Arc`, they
//! just lose the lazy paging ([`Mmap::is_mapped`] reports which mode is
//! active; the [`crate::anyprec::LoadStats`] counters surface it).

use std::fs::File;
use std::ops::Deref;

use anyhow::{Context, Result};

#[cfg(unix)]
mod sys {
    use std::ffi::c_void;

    pub const PROT_READ: i32 = 1;
    pub const MAP_PRIVATE: i32 = 2;

    extern "C" {
        pub fn mmap(addr: *mut c_void, len: usize, prot: i32, flags: i32,
                    fd: i32, offset: i64) -> *mut c_void;
        pub fn munmap(addr: *mut c_void, len: usize) -> i32;
    }
}

enum Backing {
    /// A live `mmap(2)` region (unmapped on drop).
    #[cfg(unix)]
    Map { ptr: *const u8, len: usize },
    /// Fallback: the file read into memory (non-Unix, or zero-length
    /// files, which `mmap` rejects with EINVAL).
    Owned(Vec<u8>),
}

/// A read-only view of a whole file, memory-mapped where the platform
/// allows it.
pub struct Mmap {
    backing: Backing,
}

// SAFETY: the mapping is PROT_READ + MAP_PRIVATE over a file we opened
// read-only and never mutate through this handle; an immutable byte
// region is safe to share and send across threads.
unsafe impl Send for Mmap {}
unsafe impl Sync for Mmap {}

impl Mmap {
    /// Map `path` read-only (or read it, on platforms without `mmap`).
    pub fn open(path: &str) -> Result<Mmap> {
        let file = File::open(path).with_context(|| format!("opening {path}"))?;
        let len = file
            .metadata()
            .with_context(|| format!("stat {path}"))?
            .len() as usize;
        Mmap::from_file(&file, len, path)
    }

    #[cfg(unix)]
    fn from_file(file: &File, len: usize, path: &str) -> Result<Mmap> {
        use std::os::unix::io::AsRawFd;
        if len == 0 {
            return Ok(Mmap { backing: Backing::Owned(Vec::new()) });
        }
        // SAFETY: fd is a valid open file descriptor for the duration of
        // the call; we request a fresh address (addr = null), a private
        // read-only mapping, and check for MAP_FAILED before using it.
        let ptr = unsafe {
            sys::mmap(std::ptr::null_mut(), len, sys::PROT_READ,
                      sys::MAP_PRIVATE, file.as_raw_fd(), 0)
        };
        if ptr as isize == -1 {
            // mmap can legitimately fail (e.g. special filesystems);
            // degrade to an owned read rather than erroring.
            let data = std::fs::read(path).with_context(|| format!("reading {path}"))?;
            return Ok(Mmap { backing: Backing::Owned(data) });
        }
        Ok(Mmap { backing: Backing::Map { ptr: ptr as *const u8, len } })
    }

    #[cfg(not(unix))]
    fn from_file(_file: &File, _len: usize, path: &str) -> Result<Mmap> {
        let data = std::fs::read(path).with_context(|| format!("reading {path}"))?;
        Ok(Mmap { backing: Backing::Owned(data) })
    }

    /// `true` when backed by a live kernel mapping (zero-copy, lazily
    /// paged); `false` on the owned-read fallback.
    pub fn is_mapped(&self) -> bool {
        match &self.backing {
            #[cfg(unix)]
            Backing::Map { .. } => true,
            Backing::Owned(_) => false,
        }
    }

    pub fn len(&self) -> usize {
        match &self.backing {
            #[cfg(unix)]
            Backing::Map { len, .. } => *len,
            Backing::Owned(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl Deref for Mmap {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        match &self.backing {
            #[cfg(unix)]
            // SAFETY: ptr/len describe a live PROT_READ mapping owned by
            // self; it stays valid until Drop, and Deref borrows tie the
            // slice lifetime to self.
            Backing::Map { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            Backing::Owned(v) => v,
        }
    }
}

impl Drop for Mmap {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Map { ptr, len } = self.backing {
            // SAFETY: unmapping the exact region this handle mapped;
            // Deref borrows cannot outlive self.
            unsafe {
                sys::munmap(ptr as *mut std::ffi::c_void, len);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn tmp(name: &str) -> String {
        std::env::temp_dir().join(name).to_string_lossy().into_owned()
    }

    #[test]
    fn maps_file_contents() {
        let path = tmp("dpllm_mmap_basic.bin");
        let data: Vec<u8> = (0..4096u32).map(|i| (i % 251) as u8).collect();
        std::fs::write(&path, &data).unwrap();
        let map = Mmap::open(&path).unwrap();
        assert_eq!(map.len(), data.len());
        assert_eq!(&map[..], &data[..]);
        #[cfg(unix)]
        assert!(map.is_mapped());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn empty_file_ok() {
        let path = tmp("dpllm_mmap_empty.bin");
        std::fs::write(&path, b"").unwrap();
        let map = Mmap::open(&path).unwrap();
        assert!(map.is_empty());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn arc_sharing_counts_views() {
        let path = tmp("dpllm_mmap_arc.bin");
        std::fs::write(&path, vec![7u8; 128]).unwrap();
        let map = Arc::new(Mmap::open(&path).unwrap());
        let views: Vec<Arc<Mmap>> = (0..4).map(|_| map.clone()).collect();
        assert_eq!(Arc::strong_count(&map), 5);
        for v in &views {
            assert_eq!(v[0], 7);
        }
        drop(views);
        assert_eq!(Arc::strong_count(&map), 1);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn missing_file_errors() {
        assert!(Mmap::open("/nonexistent/dpllm_nope.bin").is_err());
    }
}
