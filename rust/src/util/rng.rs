//! Small deterministic PRNG (xoshiro256**) — used by the workload
//! generators, schedulers and the property-test helpers.  `rand` is not in
//! the offline cache; this is the standard xoshiro256** algorithm.

#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        // splitmix64 expansion of the seed.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        assert!(hi > lo);
        lo + (self.next_u64() % (hi - lo) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Exponential with rate `lambda` (inter-arrival times).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }

    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.range(0, i + 1);
            xs.swap(i, j);
        }
    }

    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.range(0, xs.len())]
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// A tiny property-testing helper (proptest is not in the offline cache).
///
/// Runs `f` on `n` seeded RNGs; failures report the seed for reproduction.
pub fn for_each_seed(n: u64, f: impl Fn(&mut Rng)) {
    for seed in 0..n {
        let mut rng = Rng::new(0xD9_E7 ^ seed.wrapping_mul(0x9E3779B9));
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut rng)));
        if let Err(e) = r {
            eprintln!("property failed at seed {seed}");
            std::panic::resume_unwind(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_mean() {
        let mut r = Rng::new(7);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn range_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            let v = r.range(5, 17);
            assert!((5..17).contains(&v));
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
