//! Byte-level BPE tokenizer — the runtime twin of
//! `python/compile/tokenizer.py`.  Loads the merge table from
//! `artifacts/data/tokenizer.json` and performs greedy rank-ordered merges;
//! byte-exact round-trip parity with the Python encoder is covered by an
//! integration test against tokenized `.bin` streams.

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::util::json::Json;

pub const PAD_ID: u32 = 256;
pub const BOS_ID: u32 = 257;
pub const EOS_ID: u32 = 258;
const N_SPECIAL: u32 = 3;

pub struct Tokenizer {
    ranks: HashMap<(u32, u32), u32>,
    pieces: Vec<Vec<u8>>,
}

impl Tokenizer {
    pub fn load(path: &str) -> Result<Tokenizer> {
        let j = Json::parse_file(path)?;
        if j.str_of("type")? != "byte_bpe" {
            bail!("unsupported tokenizer type");
        }
        let merges = j.req("merges")?.as_arr()?;
        let mut ranks = HashMap::with_capacity(merges.len());
        let mut pieces: Vec<Vec<u8>> = (0..256u32).map(|b| vec![b as u8]).collect();
        pieces.push(b"<pad>".to_vec());
        pieces.push(b"<bos>".to_vec());
        pieces.push(b"<eos>".to_vec());
        for (rank, m) in merges.iter().enumerate() {
            let pair = m.as_arr().context("merge entry")?;
            let a = pair[0].as_usize()? as u32;
            let b = pair[1].as_usize()? as u32;
            ranks.insert((a, b), rank as u32);
            let mut piece = pieces[a as usize].clone();
            piece.extend_from_slice(&pieces[b as usize]);
            pieces.push(piece);
        }
        Ok(Tokenizer { ranks, pieces })
    }

    pub fn vocab_size(&self) -> usize {
        self.pieces.len()
    }

    /// Pre-tokenize with the exact semantics of Python's
    /// `re.findall(rb" ?[^\s]+|\s+", data)`:
    /// a *single* space directly before a word joins that word; any other
    /// whitespace is consumed greedily as one run (including a trailing
    /// space before the next word — greedy `\s+` eats it).
    fn pretokenize(data: &[u8]) -> Vec<&[u8]> {
        let ws = |b: u8| b.is_ascii_whitespace();
        let mut out = Vec::new();
        let mut i = 0;
        while i < data.len() {
            let start = i;
            if data[i] == b' ' && i + 1 < data.len() && !ws(data[i + 1]) {
                i += 1;
                while i < data.len() && !ws(data[i]) {
                    i += 1;
                }
            } else if ws(data[i]) {
                while i < data.len() && ws(data[i]) {
                    i += 1;
                }
            } else {
                while i < data.len() && !ws(data[i]) {
                    i += 1;
                }
            }
            out.push(&data[start..i]);
        }
        out
    }

    fn bpe_word(&self, word: &[u8]) -> Vec<u32> {
        let mut seq: Vec<u32> = word.iter().map(|&b| b as u32).collect();
        if seq.len() < 2 {
            return seq;
        }
        loop {
            let mut best: Option<(u32, usize)> = None;
            for i in 0..seq.len() - 1 {
                if let Some(&r) = self.ranks.get(&(seq[i], seq[i + 1])) {
                    if best.map_or(true, |(br, _)| r < br) {
                        best = Some((r, i));
                    }
                }
            }
            match best {
                Some((r, i)) => {
                    seq[i] = 256 + N_SPECIAL + r;
                    seq.remove(i + 1);
                }
                None => return seq,
            }
        }
    }

    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut ids = Vec::new();
        for w in Self::pretokenize(text.as_bytes()) {
            ids.extend(self.bpe_word(w));
        }
        ids
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id == PAD_ID || id == BOS_ID || id == EOS_ID {
                continue;
            }
            if let Some(p) = self.pieces.get(id as usize) {
                bytes.extend_from_slice(p);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Decode a single token id (streaming output).
    pub fn decode_one(&self, id: u32) -> String {
        self.decode(&[id])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Tokenizer {
        // merges: (116,104)->th(259), (259,101)->the(260), (32,260)->" the"(261)
        let j = r#"{"type":"byte_bpe","vocab_size":262,
                    "specials":{"pad":256,"bos":257,"eos":258},
                    "merges":[[116,104],[259,101],[32,260]]}"#;
        let tmp = std::env::temp_dir().join("dpllm_tok_test.json");
        std::fs::write(&tmp, j).unwrap();
        Tokenizer::load(tmp.to_str().unwrap()).unwrap()
    }

    #[test]
    fn encode_applies_merges_in_rank_order() {
        let t = toy();
        assert_eq!(t.encode("the"), vec![260]);
        assert_eq!(t.encode("a the"), vec![b'a' as u32, 261]);
    }

    #[test]
    fn roundtrip() {
        let t = toy();
        for s in ["the cat", "  the  the ", "héllo the", "", "a"] {
            assert_eq!(t.decode(&t.encode(s)), s, "{s:?}");
        }
    }

    #[test]
    fn pretokenize_matches_python_regex() {
        // Oracles generated with re.findall(rb" ?[^\s]+|\s+", ...).
        let cases: &[(&[u8], &[&str])] = &[
            (b"ab cd  ef", &["ab", " cd", "  ", "ef"]),
            (b"a\n b  c", &["a", "\n ", "b", "  ", "c"]),
            (b" x", &[" x"]),
            (b"  x", &["  ", "x"]),
            (b"x ", &["x", " "]),
        ];
        for (input, want) in cases {
            let toks = Tokenizer::pretokenize(input);
            let got: Vec<&str> = toks.iter()
                .map(|b| std::str::from_utf8(b).unwrap()).collect();
            assert_eq!(&got, want, "{:?}", String::from_utf8_lossy(input));
        }
    }

    #[test]
    fn specials_skipped_in_decode() {
        let t = toy();
        assert_eq!(t.decode(&[BOS_ID, b'h' as u32, EOS_ID]), "h");
    }
}
