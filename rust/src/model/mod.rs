//! Model artifacts: configs, checkpoints, calibration outputs, manifest.
//!
//! Everything the Python build pipeline wrote under `artifacts/` is loaded
//! through this module; nothing here runs Python — the artifacts are plain
//! npz / JSON / HLO-text files (DESIGN.md §5).

pub mod calib;

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::anyprec::{AnyPrecStore, GROUPS};
use crate::tensor::Tensor;
use crate::util::json::Json;
use crate::util::npz::load_npz;

/// Resolve the artifacts root: `$DPLLM_ARTIFACTS` or `<repo>/artifacts`.
pub fn artifacts_root() -> PathBuf {
    if let Ok(p) = std::env::var("DPLLM_ARTIFACTS") {
        return PathBuf::from(p);
    }
    // Walk up from cwd looking for artifacts/manifest.json (works from
    // target/, benches, examples).
    let mut dir = std::env::current_dir().unwrap_or_else(|_| PathBuf::from("."));
    loop {
        let cand = dir.join("artifacts");
        if cand.join("manifest.json").exists() || cand.is_dir() {
            return cand;
        }
        if !dir.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

pub fn art(parts: &[&str]) -> String {
    let mut p = artifacts_root();
    for part in parts {
        p.push(part);
    }
    p.to_string_lossy().into_owned()
}

/// Mirror of python `ModelConfig`.
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub rope_theta: f64,
}

impl ModelConfig {
    pub fn from_json(j: &Json) -> Result<ModelConfig> {
        Ok(ModelConfig {
            name: j.str_of("name")?,
            vocab: j.usize_of("vocab")?,
            d_model: j.usize_of("d_model")?,
            n_layers: j.usize_of("n_layers")?,
            n_heads: j.usize_of("n_heads")?,
            d_ff: j.usize_of("d_ff")?,
            max_seq: j.usize_of("max_seq")?,
            rope_theta: j.get("rope_theta").and_then(|v| v.as_f64().ok())
                .unwrap_or(10000.0),
        })
    }

    /// RoPE cos/sin tables for one absolute position ([head_dim/2] each).
    /// Computed host-side and passed to the decode graph as inputs — see
    /// the `decode_step_dual` docstring / DESIGN.md §7 for why.
    pub fn rope_tables(&self, pos: usize) -> (Vec<f32>, Vec<f32>) {
        let hd = self.head_dim();
        let half = hd / 2;
        let mut cos = Vec::with_capacity(half);
        let mut sin = Vec::with_capacity(half);
        for j in 0..half {
            let inv = 1.0 / self.rope_theta.powf(2.0 * j as f64 / hd as f64);
            let ang = pos as f64 * inv;
            cos.push(ang.cos() as f32);
            sin.push(ang.sin() as f32);
        }
        (cos, sin)
    }

    pub fn load(name: &str) -> Result<ModelConfig> {
        let path = art(&["models", name, "config.json"]);
        ModelConfig::from_json(&Json::parse_file(&path)?)
    }

    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn group_shape(&self, g: &str) -> (usize, usize) {
        let (d, f) = (self.d_model, self.d_ff);
        match g {
            "wq" | "wk" | "wv" | "wo" => (d, d),
            "wg" | "wu" => (f, d),
            "wd" => (d, f),
            _ => panic!("unknown group {g}"),
        }
    }

    pub fn n_linear(&self) -> usize {
        self.n_layers * GROUPS.len()
    }

    pub fn group_params(&self, g: &str) -> usize {
        let (o, i) = self.group_shape(g);
        o * i
    }

    /// Canonical linear enumeration: index = layer * 7 + group_pos
    /// (shared with python `assign.linear_index`).
    pub fn linear_index(&self) -> Vec<(usize, &'static str)> {
        let mut out = Vec::with_capacity(self.n_linear());
        for layer in 0..self.n_layers {
            for g in GROUPS {
                out.push((layer, g));
            }
        }
        out
    }

    pub fn kv_shape(&self) -> Vec<usize> {
        vec![self.n_layers, 2, self.n_heads, self.max_seq, self.head_dim()]
    }

    /// Total linear-weight parameter count (the `M` of Eq. 1).
    pub fn total_linear_params(&self) -> usize {
        GROUPS.iter().map(|g| self.n_layers * self.group_params(g)).sum()
    }
}

/// Non-linear (fp32) parameters of a model checkpoint.
pub struct NonLinearParams {
    pub tok_emb: Tensor,
    pub out_head: Tensor,
    pub final_norm: Tensor,
    pub ln1: Tensor,
    pub ln2: Tensor,
}

impl NonLinearParams {
    pub fn load(name: &str, cfg: &ModelConfig) -> Result<NonLinearParams> {
        let arrays = load_npz(&art(&["models", name, "ckpt.npz"]))?;
        let get = |key: &str, shape: Vec<usize>| -> Result<Tensor> {
            let a = arrays.get(key).ok_or_else(|| anyhow!("ckpt missing {key}"))?;
            if a.shape != shape {
                bail!("{key}: shape {:?}, expected {:?}", a.shape, shape);
            }
            Tensor::new(shape, a.to_f32())
        };
        Ok(NonLinearParams {
            tok_emb: get("tok_emb", vec![cfg.vocab, cfg.d_model])?,
            out_head: get("out_head", vec![cfg.vocab, cfg.d_model])?,
            final_norm: get("final_norm", vec![cfg.d_model])?,
            ln1: get("ln1", vec![cfg.n_layers, cfg.d_model])?,
            ln2: get("ln2", vec![cfg.n_layers, cfg.d_model])?,
        })
    }
}

/// Manifest entry describing one AOT-compiled graph.
#[derive(Debug, Clone)]
pub struct HloEntry {
    pub path: String,
    pub args: Vec<String>,
    pub outputs: Vec<String>,
}

pub struct Manifest {
    json: Json,
}

impl Manifest {
    pub fn load() -> Result<Manifest> {
        let path = art(&["manifest.json"]);
        Ok(Manifest { json: Json::parse_file(&path).context("manifest")? })
    }

    pub fn entry(&self, model: &str, name: &str) -> Result<HloEntry> {
        let e = self
            .json
            .req("models")?
            .req(model)
            .with_context(|| format!("model {model} not in manifest"))?
            .req("entries")?
            .req(name)
            .with_context(|| format!("entry {name}"))?;
        let strs = |key: &str| -> Result<Vec<String>> {
            Ok(match e.get(key) {
                Some(Json::Arr(a)) => a
                    .iter()
                    .map(|v| v.as_str().map(str::to_string))
                    .collect::<Result<_>>()?,
                _ => vec![],
            })
        };
        Ok(HloEntry {
            path: art(&[&e.str_of("path")?]),
            args: strs("args")?,
            outputs: strs("outputs")?,
        })
    }

    pub fn models(&self) -> Vec<String> {
        self.json
            .req("models")
            .and_then(|m| m.as_obj().map(|o| o.keys().cloned().collect()))
            .unwrap_or_default()
    }
}

/// Everything needed to instantiate a serving engine for one model.
pub struct ModelAssets {
    pub cfg: ModelConfig,
    /// Arc'd so tier-sliced views ([`ModelAssets::sliced`]) share the fp32
    /// non-linear parameters instead of re-reading the checkpoint.
    pub nl: Arc<NonLinearParams>,
    /// Shared with every [`crate::runtime::decode::DecodeSession`] built
    /// from these assets — precision rebinds re-dequantize from it long
    /// after the assets themselves are dropped.
    pub store: Arc<AnyPrecStore>,
}

impl ModelAssets {
    /// Load a model's assets, preferring the packed `anyprec.dpak`
    /// container (mmap, zero plane-byte copies, digest-verified) and
    /// falling back to the legacy `anyprec.npz`.  DPAK loads pass the
    /// version gate: a container packed for a different model is a typed
    /// refusal ([`crate::anyprec::DpakError::VersionGate`]), not a serve
    /// of foreign weights.
    pub fn load(name: &str) -> Result<ModelAssets> {
        let cfg = ModelConfig::load(name)?;
        let nl = NonLinearParams::load(name, &cfg)?;
        let dpak = art(&["models", name, "anyprec.dpak"]);
        let store = if Path::new(&dpak).exists() {
            let store = AnyPrecStore::load_dpak(&dpak)?;
            let meta = store.meta().expect("dpak loads carry meta");
            crate::anyprec::dpak::check_version_gate(meta, name, None)?;
            store
        } else {
            AnyPrecStore::load(&art(&["models", name, "anyprec.npz"]))?
        };
        if store.n_layers() != cfg.n_layers {
            bail!("anyprec store layers {} != config {}", store.n_layers(),
                  cfg.n_layers);
        }
        Ok(ModelAssets { cfg, nl: Arc::new(nl), store: Arc::new(store) })
    }

    /// A tier-sliced view sharing this asset set's nl params and container
    /// mapping, but holding only planes/LUTs ≤ `max_bits` reachable — what
    /// an economy-tier replica boots from.  Cheap: Arc clones, no weight
    /// bytes move.
    pub fn sliced(&self, max_bits: u8) -> Result<ModelAssets> {
        Ok(ModelAssets {
            cfg: self.cfg.clone(),
            nl: self.nl.clone(),
            store: Arc::new(self.store.slice(max_bits)?),
        })
    }

    /// Path a packed container for this model would live at.
    pub fn dpak_path(name: &str) -> String {
        art(&["models", name, "anyprec.dpak"])
    }
}

pub fn artifacts_available() -> bool {
    Path::new(&art(&["manifest.json"])).exists()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn group_shapes() {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 64, d_model: 32, n_layers: 2,
            n_heads: 2, d_ff: 48, max_seq: 16, rope_theta: 10000.0,
        };
        assert_eq!(cfg.group_shape("wq"), (32, 32));
        assert_eq!(cfg.group_shape("wg"), (48, 32));
        assert_eq!(cfg.group_shape("wd"), (32, 48));
        assert_eq!(cfg.n_linear(), 14);
        assert_eq!(cfg.linear_index()[8], (1, "wk"));
        assert_eq!(cfg.kv_shape(), vec![2, 2, 2, 16, 16]);
    }

    #[test]
    fn config_json_parse() {
        let j = Json::parse(
            r#"{"name":"x","vocab":1024,"d_model":192,"n_layers":6,
                "n_heads":6,"d_ff":512,"max_seq":640,"rope_theta":10000.0}"#,
        )
        .unwrap();
        let cfg = ModelConfig::from_json(&j).unwrap();
        assert_eq!(cfg.head_dim(), 32);
        assert_eq!(cfg.total_linear_params(),
                   6 * (4 * 192 * 192 + 2 * 512 * 192 + 192 * 512));
    }
}
