//! Calibration-output loading: DP-LLM selector configs (Phase 1-3 results)
//! and the static LLM-MQ / HAWQ-V2 baselines.

use anyhow::{bail, Context, Result};

use crate::anyprec::GROUPS;
use crate::model::{art, ModelConfig};
use crate::util::json::Json;
use crate::util::npz::load_npz;

/// One linear's runtime selector parameters (paper §4-5).
#[derive(Debug, Clone)]
pub struct LinearCalib {
    pub l: u8,
    pub h: u8,
    pub p: f64,
    /// Threshold T on the relative-error estimate.
    pub thr: f32,
    /// true -> linear-regression estimator; false -> JL projection.
    pub use_lin: bool,
    pub lin_a: f32,
    pub lin_b: f32,
    pub r2: f64,
}

/// A full DP-LLM configuration for one (model, budget, target).
#[derive(Debug, Clone)]
pub struct DpllmConfig {
    pub model: String,
    pub budget: u32,
    pub tag: String,
    pub target: f64,
    pub k_proj: usize,
    pub linears: Vec<LinearCalib>,
    pub n_linear_estimators: usize,
    pub n_jl_estimators: usize,
}

impl DpllmConfig {
    pub fn load(model: &str, budget: u32, tag: &str) -> Result<DpllmConfig> {
        let path = art(&["calib", model, &format!("budget{budget}"),
                         &format!("dpllm_{tag}.json")]);
        let j = Json::parse_file(&path).with_context(|| format!("config {path}"))?;
        let linears = j
            .req("linears")?
            .as_arr()?
            .iter()
            .map(|r| {
                Ok(LinearCalib {
                    l: r.f64_of("l")? as u8,
                    h: r.f64_of("h")? as u8,
                    p: r.f64_of("p")?,
                    thr: r.f64_of("thr")? as f32,
                    use_lin: r.f64_of("use_lin")? != 0.0,
                    lin_a: r.f64_of("lin_a")? as f32,
                    lin_b: r.f64_of("lin_b")? as f32,
                    r2: r.f64_of("r2")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        Ok(DpllmConfig {
            model: j.str_of("model")?,
            budget: j.f64_of("budget")? as u32,
            tag: j.str_of("tag")?,
            target: j.f64_of("target")?,
            k_proj: j.usize_of("k_proj")?,
            n_linear_estimators: j.usize_of("n_linear_estimators")?,
            n_jl_estimators: j.usize_of("n_jl_estimators")?,
            linears,
        })
    }

    /// Calibrated JL projection stacks {g: [L, K, in]} from estimators npz.
    pub fn load_estimators(&self) -> Result<Vec<(String, Vec<usize>, Vec<f32>)>> {
        let path = art(&["calib", &self.model, &format!("budget{}", self.budget),
                         &format!("estimators_{}.npz", self.tag)]);
        let arrays = load_npz(&path)?;
        let mut out = Vec::new();
        for g in GROUPS {
            let a = arrays
                .get(&format!("G_{g}"))
                .with_context(|| format!("estimators missing G_{g}"))?;
            out.push((g.to_string(), a.shape.clone(), a.to_f32()));
        }
        Ok(out)
    }

    /// Per-linear (l, h) pairs in canonical order.
    pub fn pairs(&self) -> Vec<(u8, u8)> {
        self.linears.iter().map(|r| (r.l, r.h)).collect()
    }

    /// Expected average bitwidth implied by the p values (≈ target).
    pub fn avg_p(&self, cfg: &ModelConfig) -> f64 {
        let idx = cfg.linear_index();
        let mut num = 0.0;
        let mut den = 0.0;
        for (li, (_, g)) in idx.iter().enumerate() {
            let m = cfg.group_params(g) as f64;
            num += self.linears[li].p * m;
            den += m;
        }
        num / den
    }

    /// Estimator-method memory overhead in bytes (Table 9): JL layers store
    /// a [K, in] f32 matrix each; linear-fit layers store two scalars.
    pub fn estimator_bytes(&self, cfg: &ModelConfig) -> usize {
        let idx = cfg.linear_index();
        self.linears
            .iter()
            .zip(&idx)
            .map(|(r, (_, g))| {
                if r.use_lin || r.h == r.l {
                    8
                } else {
                    let (_, i) = cfg.group_shape(g);
                    self.k_proj * i * 4
                }
            })
            .sum()
    }
}

/// Static per-linear assignment (uniform / LLM-MQ / HAWQ-V2).
#[derive(Debug, Clone)]
pub struct StaticConfig {
    pub method: String,
    pub target: f64,
    pub bits: Vec<u8>,
    pub avg_bits: f64,
}

impl StaticConfig {
    pub fn load(model: &str, budget: u32, method: &str, target: f64) -> Result<StaticConfig> {
        let path = art(&["calib", model, &format!("budget{budget}"),
                         &format!("{method}_{target:.2}.json")]);
        let j = Json::parse_file(&path)?;
        Ok(StaticConfig {
            method: j.str_of("method")?,
            target: j.f64_of("target")?,
            bits: j.req("bits")?.as_usize_vec()?.iter().map(|&b| b as u8).collect(),
            avg_bits: j.f64_of("avg_bits")?,
        })
    }

    pub fn uniform(cfg: &ModelConfig, bits: u8) -> StaticConfig {
        StaticConfig {
            method: "uniform".into(),
            target: bits as f64,
            bits: vec![bits; cfg.n_linear()],
            avg_bits: bits as f64,
        }
    }
}

/// Phase-1 output: per-linear maximum precision under the memory budget.
pub fn load_maxprec(model: &str, budget: u32) -> Result<Vec<u8>> {
    let path = art(&["calib", model, &format!("budget{budget}"), "maxprec.json"]);
    let j = Json::parse_file(&path)?;
    let bits: Vec<u8> = j.req("bits")?.as_usize_vec()?.iter().map(|&b| b as u8).collect();
    if bits.is_empty() {
        bail!("empty maxprec");
    }
    Ok(bits)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn uniform_config() {
        let cfg = ModelConfig {
            name: "t".into(), vocab: 8, d_model: 16, n_layers: 2,
            n_heads: 2, d_ff: 24, max_seq: 8, rope_theta: 10000.0,
        };
        let s = StaticConfig::uniform(&cfg, 4);
        assert_eq!(s.bits.len(), 14);
        assert!(s.bits.iter().all(|&b| b == 4));
    }

    #[test]
    fn linear_calib_json_roundtrip() {
        let j = Json::parse(
            r#"{"model":"m","budget":5,"tag":"4.00","target":4.0,
                "k_proj":64,"n_linear_estimators":3,"n_jl_estimators":4,
                "linears":[{"l":3,"h":4,"p":3.4,"thr":0.5,"use_lin":1,
                            "lin_a":0.2,"lin_b":0.01,"r2":0.95,"g_scale":1.0}]}"#,
        )
        .unwrap();
        // Emulate DpllmConfig::load's inner parsing.
        let r = &j.req("linears").unwrap().as_arr().unwrap()[0];
        assert_eq!(r.f64_of("l").unwrap() as u8, 3);
        assert!(r.f64_of("use_lin").unwrap() != 0.0);
    }
}
