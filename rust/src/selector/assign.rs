//! Runtime adaptation-set reconfiguration: the multiple-choice-knapsack
//! assignment solver (paper Appendix A / B.2) in Rust.
//!
//! The offline pipeline solves this in Python; the Rust twin lets the
//! coordinator *re-fit* a static assignment at runtime when the memory
//! budget changes (e.g., another app claims RAM on the device) without a
//! Python round trip: load the per-layer sensitivity table exported by the
//! quantizer and re-solve.  Semantics match `python/compile/assign.py`
//! (Lagrangian bisection + greedy refinement; exact up to the budget
//! granularity for separable convex costs).

use anyhow::{bail, Result};

pub const BITS: [u8; 4] = [3, 4, 5, 6];

/// Per-layer costs: `omega[i][b_idx]` = loss perturbation when layer i is
/// quantized to `BITS[b_idx]`; `m[i]` = parameter count.
pub struct AssignProblem {
    pub omega: Vec<[f64; 4]>,
    pub m: Vec<f64>,
}

impl AssignProblem {
    pub fn new(omega: Vec<[f64; 4]>, m: Vec<f64>) -> Result<AssignProblem> {
        if omega.len() != m.len() || omega.is_empty() {
            bail!("omega/m length mismatch");
        }
        Ok(AssignProblem { omega, m })
    }

    fn avg_bits(&self, choice: &[usize]) -> f64 {
        let num: f64 = choice.iter().zip(&self.m)
            .map(|(&c, &m)| BITS[c] as f64 * m).sum();
        num / self.m.iter().sum::<f64>()
    }

    fn choose(&self, lambda: f64, caps: &[usize]) -> Vec<usize> {
        self.omega.iter().zip(&self.m).zip(caps)
            .map(|((o, &m), &cap)| {
                let mut best = 0;
                let mut best_v = f64::INFINITY;
                for (bi, &b) in BITS.iter().enumerate().take(cap + 1) {
                    let v = o[bi] + lambda * b as f64 * m;
                    if v < best_v {
                        best_v = v;
                        best = bi;
                    }
                }
                best
            })
            .collect()
    }

    /// Solve for per-layer bits at average precision ≈ `target`, with an
    /// optional per-layer cap (Phase-1 maximum precisions).
    pub fn solve(&self, target: f64, max_bits: Option<&[u8]>) -> Result<Vec<u8>> {
        let caps: Vec<usize> = match max_bits {
            Some(mb) => {
                if mb.len() != self.m.len() {
                    bail!("cap length mismatch");
                }
                mb.iter()
                    .map(|&b| BITS.iter().position(|&x| x == b.clamp(3, 6)).unwrap())
                    .collect()
            }
            None => vec![BITS.len() - 1; self.m.len()],
        };
        // Lagrangian bisection (higher lambda -> cheaper bits).
        let mut lo = 0.0f64;
        let mut hi = 1.0f64;
        while self.avg_bits(&self.choose(hi, &caps)) > target && hi < 1e12 {
            hi *= 4.0;
        }
        for _ in 0..200 {
            let mid = 0.5 * (lo + hi);
            if self.avg_bits(&self.choose(mid, &caps)) > target {
                lo = mid;
            } else {
                hi = mid;
            }
        }
        let mut choice = self.choose(hi, &caps);

        // Greedy refinement toward the target from below.
        let m_sum: f64 = self.m.iter().sum();
        let budget = target * m_sum;
        let mut total: f64 = choice.iter().zip(&self.m)
            .map(|(&c, &m)| BITS[c] as f64 * m).sum();
        loop {
            let mut best: Option<(f64, usize)> = None;
            for i in 0..choice.len() {
                let c = choice[i];
                if c + 1 > caps[i] || c + 1 >= BITS.len() {
                    continue;
                }
                let dbits = (BITS[c + 1] - BITS[c]) as f64 * self.m[i];
                if total + dbits > budget + 0.005 * m_sum {
                    continue;
                }
                let gain = (self.omega[i][c] - self.omega[i][c + 1]) / dbits;
                if best.map_or(true, |(g, _)| gain > g) {
                    best = Some((gain, i));
                }
            }
            match best {
                Some((_, i)) => {
                    total += (BITS[choice[i] + 1] - BITS[choice[i]]) as f64 * self.m[i];
                    choice[i] += 1;
                }
                None => break,
            }
        }
        Ok(choice.into_iter().map(|c| BITS[c]).collect())
    }
}

/// Build a problem from the Fisher-weighted quantization errors of the
/// any-precision store (HAWQ-V2-style second-order sensitivity: the
/// fisher npz holds diag-F; error uses the store's own dequant residuals
/// against the fp checkpoint).
///
/// Candidate probing rides the incremental dequant path: each (layer,
/// group) materializes its codes once at 3 bits, then refines 3→4→5→6 one
/// plane at a time (`code_{b+1} = code_b << 1 | bit_b`) instead of
/// re-walking all planes per candidate — the 4-candidate sweep costs one
/// full dequant plus three single-plane passes.
pub fn problem_from_artifacts(model: &str) -> Result<AssignProblem> {
    use crate::anyprec::{Codes, GROUPS};
    use crate::model::{art, ModelAssets};
    use crate::util::npz::load_npz;

    let assets = ModelAssets::load(model)?;
    let fisher = load_npz(&art(&["models", model, "fisher.npz"]))?;
    let ckpt = load_npz(&art(&["models", model, "ckpt.npz"]))?;
    let mut omega = Vec::new();
    let mut m = Vec::new();
    let mut codes = Codes::new();
    let mut dq: Vec<f32> = Vec::new();
    for layer in 0..assets.cfg.n_layers {
        for g in GROUPS {
            let store = assets.store.group(g)?;
            let w = ckpt[g].to_f32();
            let f = fisher[g].to_f32();
            let n = store.out_dim * store.in_dim;
            let w_l = &w[layer * n..(layer + 1) * n];
            let f_l = &f[layer * n..(layer + 1) * n];
            dq.resize(n, 0.0);
            store.dequant_codes_into(layer, BITS[0], &mut codes)?;
            let mut row = [0f64; 4];
            for (bi, &b) in BITS.iter().enumerate() {
                if b > BITS[0] {
                    store.refine_codes_into(layer, &mut codes)?;
                }
                store.lut_map_into(layer, b, &codes, &mut dq)?;
                row[bi] = w_l.iter().zip(&dq).zip(f_l)
                    .map(|((&wv, &qv), &fv)| {
                        let d = (wv - qv) as f64;
                        fv as f64 * d * d
                    })
                    .sum();
            }
            omega.push(row);
            m.push(n as f64);
        }
    }
    AssignProblem::new(omega, m)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;

    fn toy(n: usize, seed: u64) -> AssignProblem {
        let mut rng = crate::util::rng::Rng::new(seed);
        let omega = (0..n)
            .map(|_| {
                let base = rng.f64() * 10.0 + 0.1;
                [base, base * 0.5, base * 0.25, base * 0.125]
            })
            .collect();
        let m = (0..n).map(|_| (rng.range(1, 5) * 1000) as f64).collect();
        AssignProblem::new(omega, m).unwrap()
    }

    #[test]
    fn budget_respected_property() {
        for_each_seed(25, |rng| {
            let p = toy(rng.range(4, 40), rng.next_u64());
            let target = 3.25 + rng.f64() * 2.5;
            let bits = p.solve(target, None).unwrap();
            let choice: Vec<usize> = bits.iter()
                .map(|&b| BITS.iter().position(|&x| x == b).unwrap()).collect();
            let avg = p.avg_bits(&choice);
            assert!(avg <= target + 0.006, "avg {avg} target {target}");
        });
    }

    #[test]
    fn caps_respected() {
        let p = toy(12, 7);
        let caps = vec![4u8; 12];
        let bits = p.solve(5.0, Some(&caps)).unwrap();
        assert!(bits.iter().all(|&b| b <= 4));
    }

    #[test]
    fn sensitive_layer_wins_bits() {
        let mut p = toy(8, 3);
        p.omega[0] = [1000.0, 1.0, 0.01, 0.001]; // huge benefit from 3->4
        let bits = p.solve(3.4, None).unwrap();
        // The knapsack must spend budget on the layer with the dominant
        // marginal gain before anything else.
        assert!(bits[0] >= 4, "{bits:?}");
    }

    #[test]
    fn matches_python_solver_semantics() {
        // Fixed instance with a known optimum (mirrors test_assign.py).
        let omega = vec![
            [8.0, 4.0, 2.0, 1.0],
            [8.0, 4.0, 2.0, 1.0],
            [8.0, 4.0, 2.0, 1.0],
            [8.0, 4.0, 2.0, 1.0],
        ];
        let m = vec![1.0, 1.0, 1.0, 1.0];
        let p = AssignProblem::new(omega, m).unwrap();
        let bits = p.solve(4.0, None).unwrap();
        let avg: f64 = bits.iter().map(|&b| b as f64).sum::<f64>() / 4.0;
        assert!((avg - 4.0).abs() < 0.51);
    }
}
