//! DP-LLM's runtime precision selector (paper §3-5) — L3 side.
//!
//! The AOT decode graph computes, per linear layer, a relative-error
//! estimate (hybrid: linear fit on ‖x‖ or calibrated JL projection ‖Gx‖)
//! and applies in-graph selection for the *sync* groups (o/down).  This
//! module owns the other half of the mechanism:
//!
//! * the **asynchronous** decisions for q/k/v/gate/up: compare the
//!   *previous* step's estimates against the per-layer thresholds T and
//!   feed `use_h` flags into the next step (paper Fig. 6, off the
//!   critical path),
//! * per-query **effective-bitwidth accounting** (Σ bits·Mᵢ / ΣMᵢ), which
//!   the QoS study (Table 7) and the adaptation controller consume,
//! * assembling per-group parameter stacks for upload.

pub mod assign;

use std::collections::BTreeMap;

use anyhow::{bail, Result};

use crate::anyprec::GROUPS;
use crate::model::calib::{DpllmConfig, LinearCalib, StaticConfig};
use crate::model::ModelConfig;

pub const ASYNC_GROUPS: [&str; 5] = ["wq", "wk", "wv", "wg", "wu"];

/// JL projection dimension (paper §5.1: k = 64 bounds the estimation error
/// within 15% at 91% confidence).  Must match `kernels/estimator.K_PROJ`.
pub const K_PROJ: usize = 64;

/// Per-group selector parameters in upload-ready (layer-stacked) form.
#[derive(Debug, Clone)]
pub struct GroupSelector {
    pub thr: Vec<f32>,
    pub lin_a: Vec<f32>,
    pub lin_b: Vec<f32>,
    pub use_lin: Vec<f32>,
    /// Calibrated JL stack, flattened [L, k, in]; zeros when unused.
    pub g_proj: Vec<f32>,
    pub g_shape: Vec<usize>,
}

/// A loaded engine configuration: candidate weights + selector params.
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Human tag, e.g. "dpllm@4.00" or "hawq_v2@4.00" or "uniform@4".
    pub tag: String,
    /// Per-linear candidate bits, canonical order (l == h for static).
    pub wl_bits: Vec<u8>,
    pub wh_bits: Vec<u8>,
    /// Per-linear max precision used by the prefill stacks.
    pub prefill_bits: Vec<u8>,
    pub groups: BTreeMap<String, GroupSelector>,
    /// Nominal target precision of this configuration.
    pub target: f64,
    pub dynamic: bool,
}

impl EngineConfig {
    /// Highest bitwidth any stack of this configuration dequantizes
    /// (low/high candidates and prefill) — the precision residency this
    /// config needs from the weight store.  A serving engine whose whole
    /// adaptation set needs less than 6 bits can boot from a tier-sliced
    /// store view and never touch the upper planes.
    pub fn max_bits(&self) -> u8 {
        self.wl_bits
            .iter()
            .chain(&self.wh_bits)
            .chain(&self.prefill_bits)
            .copied()
            .max()
            .unwrap_or(crate::anyprec::MAX_BITS)
    }

    /// Build from a DP-LLM calibration config (dynamic selection active).
    pub fn from_dpllm(cfg: &ModelConfig, dp: &DpllmConfig,
                      maxprec: &[u8]) -> Result<EngineConfig> {
        let n = cfg.n_linear();
        if dp.linears.len() != n {
            bail!("calib has {} linears, model wants {n}", dp.linears.len());
        }
        let idx = cfg.linear_index();
        let mut wl = vec![0u8; n];
        let mut wh = vec![0u8; n];
        let mut groups = BTreeMap::new();
        let ests = dp.load_estimators()?;
        let gmap: BTreeMap<String, (Vec<usize>, Vec<f32>)> = ests
            .into_iter()
            .map(|(g, shape, data)| (g, (shape, data)))
            .collect();
        for g in GROUPS {
            let lay: Vec<(usize, &LinearCalib)> = idx
                .iter()
                .enumerate()
                .filter(|(_, (_, gg))| *gg == g)
                .map(|(li, _)| (li, &dp.linears[li]))
                .collect();
            let (shape, data) = gmap
                .get(g)
                .cloned()
                .unwrap_or((vec![cfg.n_layers, dp.k_proj, cfg.group_shape(g).1],
                            vec![0.0; cfg.n_layers * dp.k_proj * cfg.group_shape(g).1]));
            groups.insert(g.to_string(), GroupSelector {
                thr: lay.iter().map(|(_, r)| r.thr).collect(),
                lin_a: lay.iter().map(|(_, r)| r.lin_a).collect(),
                lin_b: lay.iter().map(|(_, r)| r.lin_b).collect(),
                use_lin: lay.iter().map(|(_, r)| r.use_lin as u8 as f32).collect(),
                g_proj: data,
                g_shape: shape,
            });
            for (li, r) in lay {
                wl[li] = r.l;
                wh[li] = r.h;
            }
        }
        Ok(EngineConfig {
            tag: format!("dpllm@{}", dp.tag),
            wl_bits: wl,
            wh_bits: wh,
            prefill_bits: maxprec.to_vec(),
            groups,
            target: dp.target,
            dynamic: true,
        })
    }

    /// Build from a static assignment (LLM-MQ / HAWQ-V2 / uniform):
    /// wl == wh == assigned bits, selection disabled via +inf thresholds.
    pub fn from_static(cfg: &ModelConfig, st: &StaticConfig,
                       maxprec: &[u8]) -> Result<EngineConfig> {
        let n = cfg.n_linear();
        if st.bits.len() != n {
            bail!("static config has {} linears, model wants {n}", st.bits.len());
        }
        let mut groups = BTreeMap::new();
        for g in GROUPS {
            let l = cfg.n_layers;
            let (_, in_d) = cfg.group_shape(g);
            groups.insert(g.to_string(), GroupSelector {
                thr: vec![1e30; l],
                lin_a: vec![0.0; l],
                lin_b: vec![0.0; l],
                use_lin: vec![1.0; l],
                g_proj: vec![0.0; l * K_PROJ * in_d],
                g_shape: vec![l, K_PROJ, in_d],
            });
        }
        Ok(EngineConfig {
            tag: format!("{}@{:.2}", st.method, st.target),
            wl_bits: st.bits.clone(),
            wh_bits: st.bits.clone(),
            prefill_bits: maxprec.to_vec(),
            groups,
            target: st.target,
            dynamic: false,
        })
    }

    /// Candidate bits of one group as per-layer vectors.
    pub fn group_bits(&self, cfg: &ModelConfig, g: &str) -> (Vec<u8>, Vec<u8>) {
        let idx = cfg.linear_index();
        let mut l = Vec::with_capacity(cfg.n_layers);
        let mut h = Vec::with_capacity(cfg.n_layers);
        for (li, (_, gg)) in idx.iter().enumerate() {
            if *gg == g {
                l.push(self.wl_bits[li]);
                h.push(self.wh_bits[li]);
            }
        }
        (l, h)
    }
}

/// Mutable per-request selector state: async decisions + eff-bit stats.
pub struct SelectorState<'a> {
    cfg: &'a ModelConfig,
    ec: &'a EngineConfig,
    /// use_h flags for async groups, fed into the *next* decode step.
    pub use_h_async: BTreeMap<String, Vec<f32>>,
    /// accumulated per-step effective bits (weighted by layer size).
    bits_accum: f64,
    steps: usize,
    m_total: f64,
}

impl<'a> SelectorState<'a> {
    pub fn new(cfg: &'a ModelConfig, ec: &'a EngineConfig) -> SelectorState<'a> {
        let use_h_async = ASYNC_GROUPS
            .iter()
            .map(|g| (g.to_string(), vec![0.0; cfg.n_layers]))
            .collect();
        SelectorState {
            cfg,
            ec,
            use_h_async,
            bits_accum: 0.0,
            steps: 0,
            m_total: cfg.total_linear_params() as f64,
        }
    }

    /// Consume one step's outputs: update async decisions from this step's
    /// estimates (used next step — the paper's asynchronous estimation) and
    /// accumulate the effective bitwidth actually applied this step.
    ///
    /// `ests`/`use_eff` are per-group `[L]` vectors keyed canonically.
    pub fn observe(&mut self, ests: &BTreeMap<String, Vec<f32>>,
                   use_eff: &BTreeMap<String, Vec<f32>>) {
        for g in ASYNC_GROUPS {
            let sel = &self.ec.groups[g];
            let e = &ests[g];
            let flags = self
                .use_h_async
                .get_mut(g)
                .expect("async group present");
            for layer in 0..self.cfg.n_layers {
                flags[layer] = if e[layer] > sel.thr[layer] { 1.0 } else { 0.0 };
            }
        }
        // Effective bits this step.
        let idx = self.cfg.linear_index();
        let mut step_bits = 0.0;
        for (li, (layer, g)) in idx.iter().enumerate() {
            let m = self.cfg.group_params(g) as f64;
            let used_h = use_eff[*g][*layer] > 0.5;
            let b = if used_h { self.ec.wh_bits[li] } else { self.ec.wl_bits[li] };
            step_bits += b as f64 * m;
        }
        self.bits_accum += step_bits / self.m_total;
        self.steps += 1;
    }

    /// Re-bind this state to a different engine configuration of the same
    /// model (mid-stream target re-selection, ServingCore).  Accumulated
    /// effective-bit statistics and the pending async flags carry over —
    /// the flags are per-layer booleans whose meaning ("run this layer's
    /// async groups at the high candidate next step") is config-independent;
    /// the next [`SelectorState::observe`] re-derives them against the new
    /// thresholds.
    pub fn rebind(&mut self, cfg: &'a ModelConfig, ec: &'a EngineConfig) {
        debug_assert_eq!(cfg.n_layers, self.cfg.n_layers, "rebind across models");
        self.cfg = cfg;
        self.ec = ec;
    }

    /// Mean effective bitwidth over the observed decode steps.
    pub fn effective_bits(&self) -> f64 {
        if self.steps == 0 {
            return f64::NAN;
        }
        self.bits_accum / self.steps as f64
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    pub fn reset_stats(&mut self) {
        self.bits_accum = 0.0;
        self.steps = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy_cfg() -> ModelConfig {
        ModelConfig {
            name: "t".into(), vocab: 8, d_model: 16, n_layers: 2,
            n_heads: 2, d_ff: 24, max_seq: 8, rope_theta: 10000.0,
        }
    }

    fn toy_engine(cfg: &ModelConfig) -> EngineConfig {
        let st = StaticConfig::uniform(cfg, 4);
        let mut ec = EngineConfig::from_static(cfg, &st, &vec![5; cfg.n_linear()]).unwrap();
        // make it dynamic with candidate (3,4) everywhere, thr = 1.0
        ec.wl_bits = vec![3; cfg.n_linear()];
        ec.wh_bits = vec![4; cfg.n_linear()];
        for g in GROUPS {
            ec.groups.get_mut(g).unwrap().thr = vec![1.0; cfg.n_layers];
        }
        ec.dynamic = true;
        ec
    }

    fn maps(cfg: &ModelConfig, val: f32) -> BTreeMap<String, Vec<f32>> {
        GROUPS
            .iter()
            .map(|g| (g.to_string(), vec![val; cfg.n_layers]))
            .collect()
    }

    #[test]
    fn async_decisions_follow_thresholds() {
        let cfg = toy_cfg();
        let ec = toy_engine(&cfg);
        let mut st = SelectorState::new(&cfg, &ec);
        // estimates above thr=1.0 -> all async groups flip to high.
        st.observe(&maps(&cfg, 2.0), &maps(&cfg, 0.0));
        for g in ASYNC_GROUPS {
            assert!(st.use_h_async[g].iter().all(|&f| f == 1.0), "{g}");
        }
        st.observe(&maps(&cfg, 0.5), &maps(&cfg, 0.0));
        for g in ASYNC_GROUPS {
            assert!(st.use_h_async[g].iter().all(|&f| f == 0.0), "{g}");
        }
    }

    #[test]
    fn effective_bits_bounds() {
        let cfg = toy_cfg();
        let ec = toy_engine(&cfg);
        let mut st = SelectorState::new(&cfg, &ec);
        st.observe(&maps(&cfg, 0.0), &maps(&cfg, 0.0)); // all low
        assert!((st.effective_bits() - 3.0).abs() < 1e-9);
        st.observe(&maps(&cfg, 0.0), &maps(&cfg, 1.0)); // all high
        assert!((st.effective_bits() - 3.5).abs() < 1e-9);
    }

    #[test]
    fn effective_bits_weighted_mix() {
        let cfg = toy_cfg();
        let ec = toy_engine(&cfg);
        let mut st = SelectorState::new(&cfg, &ec);
        // only wq at high
        let mut use_eff = maps(&cfg, 0.0);
        use_eff.insert("wq".into(), vec![1.0; cfg.n_layers]);
        st.observe(&maps(&cfg, 0.0), &use_eff);
        let m_q = (2 * 16 * 16) as f64;
        let m_tot = cfg.total_linear_params() as f64;
        let want = 3.0 + m_q / m_tot;
        assert!((st.effective_bits() - want).abs() < 1e-9);
    }

    #[test]
    fn static_config_disables_selection() {
        let cfg = toy_cfg();
        let st = StaticConfig::uniform(&cfg, 4);
        let ec = EngineConfig::from_static(&cfg, &st, &vec![6; cfg.n_linear()]).unwrap();
        assert!(!ec.dynamic);
        assert!(ec.groups["wq"].thr.iter().all(|&t| t > 1e29));
        let (l, h) = ec.group_bits(&cfg, "wd");
        assert_eq!(l, vec![4, 4]);
        assert_eq!(h, vec![4, 4]);
    }
}
