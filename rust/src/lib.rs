//! DP-LLM: Runtime Model Adaptation with Dynamic Layer-wise Precision
//! Assignment (NeurIPS 2025) — the L3 Rust coordinator of the three-layer
//! Rust + JAX + Pallas reproduction (see README.md for the quickstart).
//!
//! Layer map (see DESIGN.md):
//! - L1: Pallas kernels (`python/compile/kernels/`), build-time.
//! - L2: JAX model + serving graphs (`python/compile/model.py`), AOT-lowered
//!   to HLO text by `python/compile/aot.py` — including the batched
//!   `decode_step_b{2,4,8}` entries behind continuous batching
//!   (DESIGN.md §Batching), the `verify_step_g{2,4}` entries behind
//!   self-speculative decoding (DESIGN.md §Speculation) and the
//!   `prefill_chunk_{64,128}` entries behind chunked
//!   scheduler-interleaved prompt ingestion (DESIGN.md §Prefill).
//! - L3: this crate — loads the HLO artifacts via PJRT ([`runtime`]), owns
//!   the request path: tokenization ([`tokenizer`]), dynamic per-layer
//!   precision selection ([`selector`]), QoS adaptation, scheduling and
//!   batched dispatch ([`coordinator`]), serving ([`server`]), evaluation
//!   harnesses ([`evalharness`]) and device cost models ([`costmodel`]).

pub mod anyprec;
pub mod bench_support;
pub mod cli;
pub mod coordinator;
pub mod costmodel;
pub mod evalharness;
pub mod model;
pub mod obs;
pub mod runtime;
pub mod selector;
pub mod server;
pub mod tensor;
pub mod tokenizer;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};

/// CLI dispatcher (`dpllm <subcommand>`).
pub fn cli_main(args: &[String]) -> Result<()> {
    cli::run(args)
}
