//! Observability (DESIGN.md §Observability): the flight recorder
//! ([`trace`]), live log2-bucket latency histograms ([`hist`]), the
//! Prometheus text exposition ([`prom`]) and structured leveled logging
//! ([`log`], the [`dpllm_log!`](crate::dpllm_log) macro).
//!
//! The serving stack records into the process-wide [`trace::global`]
//! tracer — request lifecycle, precision decisions (selector epoch
//! re-assignments, pressure downshifts, γ changes, `swap_bits`
//! rebinds), KV events and fleet events — exported as Chrome
//! trace-event JSON via `GET /trace` on both servers and
//! `dpllm serve --trace-out <path>`.  Histograms feed per-SLO-class
//! TTFT/ITL/queue-delay percentiles into `/metrics` and
//! `GET /metrics?format=prometheus`.

pub mod hist;
pub mod log;
pub mod prom;
pub mod trace;

pub use hist::{HistogramSet, LogHistogram, SloClass};
pub use trace::{global as global_tracer, EventKind, TraceSnapshot, Tracer};
