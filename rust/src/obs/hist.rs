//! Live latency histograms: fixed log2-bucket, allocation-free record
//! path, mergeable (DESIGN.md §Observability).
//!
//! A [`LogHistogram`] holds 64 power-of-two buckets over microseconds:
//! bucket `i ≥ 1` covers `[2^(i-1), 2^i - 1]` µs, bucket 0 holds zero.
//! Recording is an increment into a fixed array — no allocation, no
//! sort — so the serving hot path can feed live TTFT/ITL/queue-delay
//! distributions at token cadence.  Percentile queries return the
//! bucket's **upper bound**, clamped to the observed maximum, which
//! over-reports a true (nearest-rank) percentile by at most 2× — the
//! bound the oracle-agreement unit test pins across random workloads.
//!
//! [`HistogramSet`] is the serving bundle: TTFT, ITL and queue-delay
//! histograms keyed by SLO class (premium = deadline or finite
//! per-token budget; economy = best-effort), feeding both the
//! `/metrics` JSON summaries and the Prometheus text exposition.

use crate::util::json::Json;

/// Bucket count: covers 0 .. 2^63 µs (≫ any latency).
pub const BUCKETS: usize = 64;

/// Fixed log2-bucket histogram over non-negative µs values.
#[derive(Debug, Clone)]
pub struct LogHistogram {
    buckets: [u64; BUCKETS],
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram::new()
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram { buckets: [0; BUCKETS], count: 0, sum_us: 0, max_us: 0 }
    }

    /// Bucket index of a value: 0 for 0, else its bit length (clamped).
    #[inline]
    fn bucket_of(us: u64) -> usize {
        ((64 - us.leading_zeros()) as usize).min(BUCKETS - 1)
    }

    /// Upper bound (µs) of bucket `i` — what percentile queries report.
    fn upper_bound(i: usize) -> u64 {
        if i == 0 {
            0
        } else {
            (1u64 << i) - 1
        }
    }

    /// Record one value.  Allocation-free: one array increment.
    #[inline]
    pub fn record_us(&mut self, us: u64) {
        self.buckets[Self::bucket_of(us)] += 1;
        self.count += 1;
        self.sum_us = self.sum_us.saturating_add(us);
        self.max_us = self.max_us.max(us);
    }

    /// Record a millisecond value (negative/NaN clamps to 0).
    #[inline]
    pub fn record_ms(&mut self, ms: f64) {
        let us = if ms.is_finite() && ms > 0.0 { (ms * 1e3).round() as u64 } else { 0 };
        self.record_us(us);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Nearest-rank percentile (p in [0, 100]) as the matched bucket's
    /// upper bound, clamped to the observed max.  For any true sample
    /// percentile `v` the result `r` satisfies `v ≤ r < 2·v` (and
    /// `r = 0` exactly when `v = 0`).  Returns 0 on an empty histogram.
    /// The top bucket is open-ended (values ≥ 2^63 µs clamp into it, so
    /// its nominal upper bound can underflow what it holds); a rank
    /// landing there reports the observed max, keeping `v ≤ r`
    /// unconditional.
    pub fn percentile_us(&self, p: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((p.clamp(0.0, 100.0) / 100.0 * self.count as f64).ceil() as u64)
            .clamp(1, self.count);
        let mut seen = 0u64;
        for (i, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                return if i == BUCKETS - 1 {
                    self.max_us
                } else {
                    Self::upper_bound(i).min(self.max_us)
                };
            }
        }
        self.max_us
    }

    /// Same percentile in milliseconds (for report JSON).
    pub fn percentile_ms(&self, p: f64) -> f64 {
        self.percentile_us(p) as f64 / 1e3
    }

    /// Fold another histogram in (ring merges, fleet aggregation).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum_us = self.sum_us.saturating_add(other.sum_us);
        self.max_us = self.max_us.max(other.max_us);
    }

    /// Cumulative (bucket upper bound µs, count ≤ bound) pairs up to the
    /// highest non-empty bucket — the Prometheus `_bucket` series shape.
    pub fn cumulative(&self) -> Vec<(u64, u64)> {
        let last = match self.buckets.iter().rposition(|&n| n > 0) {
            Some(i) => i,
            None => return Vec::new(),
        };
        let mut out = Vec::with_capacity(last + 1);
        let mut seen = 0u64;
        for i in 0..=last {
            seen += self.buckets[i];
            out.push((Self::upper_bound(i), seen));
        }
        out
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }
}

/// SLO class key for the serving histograms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloClass {
    Economy = 0,
    Premium = 1,
}

impl SloClass {
    pub fn from_premium(premium: bool) -> SloClass {
        if premium {
            SloClass::Premium
        } else {
            SloClass::Economy
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SloClass::Economy => "economy",
            SloClass::Premium => "premium",
        }
    }

    pub fn all() -> [SloClass; 2] {
        [SloClass::Economy, SloClass::Premium]
    }
}

/// The serving latency bundle: TTFT / ITL / queue-delay histograms per
/// SLO class.  One lives in the engine's `MetricsRegistry` (single-core
/// serving), one in the `Router` (fleet-level, recorded once per
/// terminal `Done`) — never both for the same request.
#[derive(Debug, Clone, Default)]
pub struct HistogramSet {
    ttft: [LogHistogram; 2],
    itl: [LogHistogram; 2],
    queue: [LogHistogram; 2],
}

impl HistogramSet {
    pub fn new() -> HistogramSet {
        HistogramSet::default()
    }

    /// Record one finished request's latency triple (ms).
    pub fn record(&mut self, class: SloClass, ttft_ms: f64, itl_ms: f64, queue_ms: f64) {
        let i = class as usize;
        self.ttft[i].record_ms(ttft_ms);
        self.itl[i].record_ms(itl_ms);
        self.queue[i].record_ms(queue_ms);
    }

    pub fn merge(&mut self, other: &HistogramSet) {
        for i in 0..2 {
            self.ttft[i].merge(&other.ttft[i]);
            self.itl[i].merge(&other.itl[i]);
            self.queue[i].merge(&other.queue[i]);
        }
    }

    /// The named metric families, for exposition loops.
    pub fn families(&self) -> [(&'static str, &[LogHistogram; 2]); 3] {
        [("ttft_ms", &self.ttft), ("itl_ms", &self.itl), ("queue_delay_ms", &self.queue)]
    }

    /// Per-class percentile summary for the `/metrics` JSON:
    /// `{"premium": {"n": …, "ttft_ms_p50": …, …}, "economy": {…}}`.
    pub fn json(&self) -> Json {
        let mut top = Json::obj();
        for class in SloClass::all() {
            let i = class as usize;
            let mut c = Json::obj();
            c.set("n", self.ttft[i].count() as i64);
            for (name, hists) in self.families() {
                let h = &hists[i];
                c.set(&format!("{name}_p50"), h.percentile_ms(50.0))
                    .set(&format!("{name}_p90"), h.percentile_ms(90.0))
                    .set(&format!("{name}_p99"), h.percentile_ms(99.0))
                    .set(&format!("{name}_mean"), h.mean_us() / 1e3);
            }
            top.set(class.name(), c);
        }
        top
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::for_each_seed;
    use crate::util::stats::percentile_nearest_rank;

    #[test]
    fn bucket_edges() {
        assert_eq!(LogHistogram::bucket_of(0), 0);
        assert_eq!(LogHistogram::bucket_of(1), 1);
        assert_eq!(LogHistogram::bucket_of(2), 2);
        assert_eq!(LogHistogram::bucket_of(3), 2);
        assert_eq!(LogHistogram::bucket_of(4), 3);
        assert_eq!(LogHistogram::bucket_of(1023), 10);
        assert_eq!(LogHistogram::bucket_of(1024), 11);
        assert_eq!(LogHistogram::bucket_of(u64::MAX), BUCKETS - 1);
        assert_eq!(LogHistogram::upper_bound(1), 1);
        assert_eq!(LogHistogram::upper_bound(10), 1023);
    }

    /// Histogram percentiles agree with the nearest-rank oracle within
    /// the documented factor-2 envelope, across 25 random workloads
    /// spanning ~5 decades of latency.
    #[test]
    fn percentile_agrees_with_nearest_rank_oracle() {
        for_each_seed(25, |rng| {
            let mut h = LogHistogram::new();
            let mut xs: Vec<f64> = Vec::new();
            let n = rng.range(50, 2000);
            for _ in 0..n {
                // Log-uniform µs in [1, 10^5] with occasional zeros.
                let us = if rng.bool(0.02) {
                    0
                } else {
                    (10f64.powf(rng.f64() * 5.0)) as u64
                };
                h.record_us(us);
                xs.push(us as f64);
            }
            for p in [50.0, 90.0, 99.0, 99.9] {
                let oracle = percentile_nearest_rank(&xs, p).unwrap();
                let got = h.percentile_us(p) as f64;
                assert!(
                    got >= oracle,
                    "p{p}: histogram {got} under-reports oracle {oracle}"
                );
                assert!(
                    got <= (2.0 * oracle).max(oracle + 1.0),
                    "p{p}: histogram {got} above 2x oracle {oracle}"
                );
            }
        });
    }

    #[test]
    fn merge_equals_recording_into_one() {
        let mut a = LogHistogram::new();
        let mut b = LogHistogram::new();
        let mut whole = LogHistogram::new();
        for i in 0..500u64 {
            let v = i * 37 % 10_000;
            if i % 2 == 0 {
                a.record_us(v);
            } else {
                b.record_us(v);
            }
            whole.record_us(v);
        }
        a.merge(&b);
        assert_eq!(a.count(), whole.count());
        assert_eq!(a.sum_us(), whole.sum_us());
        assert_eq!(a.max_us(), whole.max_us());
        for p in [50.0, 90.0, 99.0] {
            assert_eq!(a.percentile_us(p), whole.percentile_us(p));
        }
        assert_eq!(a.cumulative(), whole.cumulative());
    }

    #[test]
    fn top_bucket_percentile_reports_observed_max() {
        // Values ≥ 2^63 µs clamp into the open-ended top bucket, whose
        // nominal upper bound (2^63 - 1) sits below them; the reported
        // percentile must still satisfy v ≤ r.
        let mut h = LogHistogram::new();
        h.record_us(1);
        h.record_us(u64::MAX - 3);
        assert_eq!(h.percentile_us(50.0), 1);
        assert_eq!(h.percentile_us(99.0), u64::MAX - 3);
    }

    #[test]
    fn empty_histogram_is_all_zero() {
        let h = LogHistogram::new();
        assert_eq!(h.percentile_us(99.0), 0);
        assert_eq!(h.mean_us(), 0.0);
        assert!(h.cumulative().is_empty());
    }

    #[test]
    fn histogram_set_keys_classes_separately() {
        let mut s = HistogramSet::new();
        s.record(SloClass::Premium, 5.0, 0.5, 1.0);
        s.record(SloClass::Premium, 7.0, 0.6, 1.5);
        s.record(SloClass::Economy, 50.0, 2.0, 20.0);
        let j = s.json();
        let prem = j.get("premium").unwrap();
        let eco = j.get("economy").unwrap();
        assert_eq!(prem.f64_of("n").unwrap(), 2.0);
        assert_eq!(eco.f64_of("n").unwrap(), 1.0);
        assert!(prem.f64_of("ttft_ms_p99").unwrap() < eco.f64_of("ttft_ms_p99").unwrap());
        // Upper-bound semantics: p99 is ≥ the recorded max for premium.
        assert!(prem.f64_of("ttft_ms_p99").unwrap() >= 7.0 - 1e-9);
    }
}
