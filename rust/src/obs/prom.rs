//! Prometheus text exposition (version 0.0.4) over the existing JSON
//! counter serializers, plus a line-format validator (DESIGN.md
//! §Observability).
//!
//! `GET /metrics?format=prometheus` flattens the same JSON objects the
//! default endpoint serves — summary scalars, the full runtime counter
//! families, the memory report, `router_*` counters, per-replica rows —
//! into `dpllm_*` gauge lines, and renders the per-class TTFT / ITL /
//! queue-delay [`HistogramSet`]s as native Prometheus histograms
//! (`_bucket{le=…}` / `_sum` / `_count`).  No client library exists in
//! the offline crate cache, so [`validate`] is the hand-rolled
//! line-format checker the unit tests (and the `obs_micro` bench) hold
//! the exposition against.

use anyhow::{bail, Result};

use super::hist::{HistogramSet, SloClass};
use crate::util::json::Json;

/// Prefix every exposed metric name carries.
pub const PREFIX: &str = "dpllm";

/// Sanitize one JSON key into a Prometheus metric-name segment
/// (`[a-zA-Z0-9_]`, leading digit guarded by the `dpllm_` prefix).
fn sanitize(key: &str) -> String {
    key.chars()
        .map(|c| if c.is_ascii_alphanumeric() || c == '_' { c } else { '_' })
        .collect()
}

/// Append one metric line: `name{labels} value`.
pub fn push_metric(out: &mut String, name: &str, labels: &[(&str, &str)], value: f64) {
    out.push_str(name);
    if !labels.is_empty() {
        out.push('{');
        for (i, (k, v)) in labels.iter().enumerate() {
            if i > 0 {
                out.push(',');
            }
            out.push_str(k);
            out.push_str("=\"");
            // Label values escape backslash, quote and newline.
            for c in v.chars() {
                match c {
                    '\\' => out.push_str("\\\\"),
                    '"' => out.push_str("\\\""),
                    '\n' => out.push_str("\\n"),
                    c => out.push(c),
                }
            }
            out.push('"');
        }
        out.push('}');
    }
    out.push(' ');
    if value.is_finite() {
        // Integral values print without a fraction (counter-friendly).
        if value.fract() == 0.0 && value.abs() < 9e15 {
            let _ = std::fmt::Write::write_fmt(out, format_args!("{}", value as i64));
        } else {
            let _ = std::fmt::Write::write_fmt(out, format_args!("{value}"));
        }
    } else if value.is_nan() {
        out.push_str("NaN");
    } else if value > 0.0 {
        out.push_str("+Inf");
    } else {
        out.push_str("-Inf");
    }
    out.push('\n');
}

/// Flatten a JSON object's numeric/bool leaves into `dpllm_<path>`
/// gauges, recursing into nested objects with `_`-joined paths.
/// Strings and arrays are skipped (arrays with per-row identity go
/// through [`replica_rows`]).
pub fn flatten_object(out: &mut String, path: &str, j: &Json) {
    if let Json::Obj(m) = j {
        for (k, v) in m {
            let name = if path.is_empty() {
                format!("{PREFIX}_{}", sanitize(k))
            } else {
                format!("{path}_{}", sanitize(k))
            };
            match v {
                Json::Num(x) => push_metric(out, &name, &[], *x),
                Json::Bool(b) => push_metric(out, &name, &[], if *b { 1.0 } else { 0.0 }),
                Json::Obj(_) => flatten_object(out, &name, v),
                _ => {}
            }
        }
    }
}

/// Expose a `replicas` array (from `metrics::replicas_json`) as
/// `dpllm_replica_<field>{replica="<id>",tier="…"}` gauges.
pub fn replica_rows(out: &mut String, rows: &[Json]) {
    for r in rows {
        let id = r.f64_of("id").unwrap_or(-1.0);
        let id_s = format!("{}", id as i64);
        let tier = r.str_of("tier").unwrap_or_default();
        if let Json::Obj(m) = r {
            for (k, v) in m {
                if k == "id" || k == "tier" {
                    continue;
                }
                let val = match v {
                    Json::Num(x) => *x,
                    Json::Bool(b) => {
                        if *b {
                            1.0
                        } else {
                            0.0
                        }
                    }
                    _ => continue,
                };
                let name = format!("{PREFIX}_replica_{}", sanitize(k));
                push_metric(
                    out,
                    &name,
                    &[("replica", id_s.as_str()), ("tier", tier.as_str())],
                    val,
                );
            }
        }
    }
}

/// Render a [`HistogramSet`] as native Prometheus histogram series,
/// one per metric family × SLO class.  Bucket bounds are the log2
/// upper bounds in milliseconds.
pub fn histogram_set(out: &mut String, hs: &HistogramSet) {
    for (family, hists) in hs.families() {
        let name = format!("{PREFIX}_{}", sanitize(family));
        out.push_str(&format!("# TYPE {name} histogram\n"));
        for class in SloClass::all() {
            let h = &hists[class as usize];
            for (bound_us, cum) in h.cumulative() {
                let le = format!("{}", bound_us as f64 / 1e3);
                push_metric(
                    out,
                    &format!("{name}_bucket"),
                    &[("class", class.name()), ("le", le.as_str())],
                    cum as f64,
                );
            }
            push_metric(
                out,
                &format!("{name}_bucket"),
                &[("class", class.name()), ("le", "+Inf")],
                h.count() as f64,
            );
            push_metric(
                out,
                &format!("{name}_sum"),
                &[("class", class.name())],
                h.sum_us() as f64 / 1e3,
            );
            push_metric(
                out,
                &format!("{name}_count"),
                &[("class", class.name())],
                h.count() as f64,
            );
        }
    }
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_' || c == ':')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

fn valid_label_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().is_some_and(|c| c.is_ascii_alphabetic() || c == '_')
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_')
}

fn valid_value(s: &str) -> bool {
    matches!(s, "+Inf" | "-Inf" | "NaN") || s.parse::<f64>().is_ok()
}

/// Scan a label body starting just past the opening `{`, quote-aware:
/// `,`, `}` and `=` inside quoted values — and `\`-escaped characters
/// within them — do not terminate pairs (replica tier labels are
/// comma-joined, e.g. `tier="3.25,3.50"`).  Returns the byte offset
/// just past the closing `}`, or what went wrong.
fn scan_labels(body: &str) -> std::result::Result<usize, String> {
    let b = body.as_bytes();
    let mut i = 0usize;
    loop {
        match b.get(i) {
            None => return Err("unterminated label set".to_string()),
            Some(b'}') => return Ok(i + 1),
            Some(_) => {}
        }
        let start = i;
        while i < b.len() && (b[i].is_ascii_alphanumeric() || b[i] == b'_') {
            i += 1;
        }
        let name = &body[start..i];
        if !valid_label_name(name) {
            return Err(format!("bad label name {name:?}"));
        }
        if b.get(i) != Some(&b'=') {
            return Err(format!("label {name:?} without '='"));
        }
        i += 1;
        if b.get(i) != Some(&b'"') {
            return Err(format!("unquoted value for label {name:?}"));
        }
        i += 1;
        loop {
            match b.get(i) {
                None => return Err(format!("unterminated value for label {name:?}")),
                Some(b'\\') => {
                    if i + 1 >= b.len() {
                        return Err(format!("dangling escape in label {name:?}"));
                    }
                    i += 2;
                }
                Some(b'"') => {
                    i += 1;
                    break;
                }
                Some(_) => i += 1,
            }
        }
        match b.get(i) {
            Some(b',') => i += 1,
            Some(b'}') => return Ok(i + 1),
            _ => return Err(format!("expected ',' or '}}' after label {name:?}")),
        }
    }
}

/// Validate Prometheus text-exposition line format: every non-comment,
/// non-blank line must be `name[{label="value",…}] value`, with label
/// values scanned quote-aware so legal commas, braces and `\` escapes
/// inside values pass.  This is the parser stand-in
/// for a scrape (no prometheus client exists in the offline crate
/// cache) — unit tests hold every exposition we emit against it.
pub fn validate(text: &str) -> Result<()> {
    for (ln, line) in text.lines().enumerate() {
        let line = line.trim_end();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let (name_part, rest) = match line.find(|c| c == '{' || c == ' ') {
            Some(i) => (&line[..i], &line[i..]),
            None => bail!("line {}: no value separator: {line:?}", ln + 1),
        };
        if !valid_name(name_part) {
            bail!("line {}: bad metric name {name_part:?}", ln + 1);
        }
        let value_part = if let Some(label_body) = rest.strip_prefix('{') {
            match scan_labels(label_body) {
                Ok(end) => label_body[end..].trim_start(),
                Err(why) => bail!("line {}: {why}: {line:?}", ln + 1),
            }
        } else {
            rest.trim_start()
        };
        if !valid_value(value_part) {
            bail!("line {}: bad sample value {value_part:?}", ln + 1);
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn push_metric_formats_scalars_and_labels() {
        let mut out = String::new();
        push_metric(&mut out, "dpllm_uploads", &[], 42.0);
        push_metric(&mut out, "dpllm_rate", &[("class", "premium")], 0.75);
        push_metric(&mut out, "dpllm_x_bucket", &[("le", "+Inf")], 7.0);
        assert_eq!(
            out,
            "dpllm_uploads 42\ndpllm_rate{class=\"premium\"} 0.75\n\
             dpllm_x_bucket{le=\"+Inf\"} 7\n"
        );
        validate(&out).unwrap();
    }

    #[test]
    fn flatten_covers_nested_objects_and_skips_strings() {
        let mut j = Json::obj();
        j.set("uploads", 10i64).set("arrival", "poisson");
        let mut mem = Json::obj();
        mem.set("kv_in_use_bytes", 300i64);
        j.set("memory", mem);
        let mut out = String::new();
        flatten_object(&mut out, "", &j);
        assert!(out.contains("dpllm_uploads 10\n"));
        assert!(out.contains("dpllm_memory_kv_in_use_bytes 300\n"));
        assert!(!out.contains("poisson"), "strings are not samples");
        validate(&out).unwrap();
    }

    #[test]
    fn replica_rows_carry_identity_labels() {
        let mut r = Json::obj();
        r.set("id", 1i64)
            .set("tier", "4.50,4.75")
            .set("premium", true)
            .set("queue_depth", 3i64)
            .set("tokens_per_s", 120.5);
        let mut out = String::new();
        replica_rows(&mut out, &[r]);
        assert!(out.contains(
            "dpllm_replica_queue_depth{replica=\"1\",tier=\"4.50,4.75\"} 3\n"
        ));
        assert!(out.contains("dpllm_replica_premium{replica=\"1\",tier=\"4.50,4.75\"} 1\n"));
        validate(&out).unwrap();
    }

    #[test]
    fn histogram_exposition_is_cumulative_and_valid() {
        let mut hs = HistogramSet::new();
        hs.record(SloClass::Premium, 5.0, 0.5, 1.0);
        hs.record(SloClass::Premium, 9.0, 0.7, 2.0);
        hs.record(SloClass::Economy, 40.0, 2.0, 10.0);
        let mut out = String::new();
        histogram_set(&mut out, &hs);
        validate(&out).unwrap();
        assert!(out.contains("# TYPE dpllm_ttft_ms histogram"));
        assert!(out.contains("dpllm_ttft_ms_bucket{class=\"premium\",le=\"+Inf\"} 2\n"));
        assert!(out.contains("dpllm_ttft_ms_count{class=\"premium\"} 2\n"));
        assert!(out.contains("dpllm_itl_ms_count{class=\"economy\"} 1\n"));
        // +Inf count equals _count for every class (cumulative sanity).
        for class in ["premium", "economy"] {
            let inf = format!("dpllm_queue_delay_ms_bucket{{class=\"{class}\",le=\"+Inf\"}}");
            assert!(out.contains(&inf), "missing +Inf bucket for {class}");
        }
    }

    #[test]
    fn validator_rejects_malformed_lines() {
        assert!(validate("ok_metric 1\n").is_ok());
        assert!(validate("# HELP anything goes\n").is_ok());
        assert!(validate("9leading_digit 1\n").is_err());
        assert!(validate("name{le=\"1\"\n").is_err(), "unterminated labels");
        assert!(validate("name{le=unquoted} 1\n").is_err());
        assert!(validate("name notanumber\n").is_err());
        assert!(validate("name{class=\"p\"} +Inf\n").is_ok());
    }

    #[test]
    fn validator_is_quote_aware_inside_label_values() {
        // Comma-joined tier labels are legal exposition — the scanner
        // must not treat the ',' inside the quotes as a pair boundary.
        assert!(validate("m{tier=\"3.25,3.50\"} 1\n").is_ok());
        // Nor a '}' or '=' inside the quotes as the label-set close.
        assert!(validate("m{v=\"a}b\",w=\"c=d\"} 1\n").is_ok());
        // Escapes produced by push_metric stay inside the value.
        assert!(validate("m{v=\"a\\\"b\\\\\"} 1\n").is_ok());
        // A value that never closes its quote is still rejected, even
        // though a bare '}' appears later on the line.
        assert!(validate("m{v=\"a,b} 1\n").is_err());
        // Roundtrip: the emitter's escaping parses back.
        let mut out = String::new();
        push_metric(&mut out, "m", &[("tier", "3.25,3.50"), ("q", "a\"b\\c")], 2.0);
        validate(&out).unwrap();
    }
}
