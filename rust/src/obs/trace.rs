//! Flight recorder: a bounded, overwrite-oldest trace ring of typed,
//! timestamped events (DESIGN.md §Observability).
//!
//! Design constraints, in order:
//! 1. **Disabled cost ~0.** Serving code calls [`Tracer::record`]
//!    unconditionally; when tracing is off the call is one relaxed
//!    atomic load and a branch (≤ ~25 ns — measured by `obs_micro`).
//! 2. **No cross-thread contention on the hot path.** Replica workers,
//!    the router executor and the engine thread each record into their
//!    own per-thread ring; a lock is taken only on a ring the recording
//!    thread owns (uncontended except while a drain is merging).
//! 3. **Bounded memory, no silent loss.** Each ring holds the last
//!    `cap` events; older events are overwritten and counted in an
//!    exact per-ring drop counter, surfaced by every snapshot.
//!
//! Events are plain `Copy` data (precision values carried as integer
//! milli-bits, never strings) so the record path never allocates.  The
//! merged snapshot exports as Chrome trace-event JSON (Perfetto /
//! `chrome://tracing` loadable): one track per request (pid
//! [`PID_REQUESTS`]), one per replica (pid [`PID_FLEET`]), one per
//! precision decision stream (pid [`PID_PRECISION`]).

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Instant;

use crate::util::json::Json;

/// Chrome-trace process id grouping the per-request lifecycle tracks.
pub const PID_REQUESTS: u64 = 1;
/// Chrome-trace process id grouping the per-replica fleet tracks.
pub const PID_FLEET: u64 = 2;
/// Chrome-trace process id grouping the precision-decision tracks.
pub const PID_PRECISION: u64 = 3;

/// Default per-thread ring capacity (events).  At ~48 bytes/event this
/// bounds a thread's recorder at ~0.8 MB.
pub const DEFAULT_RING_CAP: usize = 16_384;

/// One typed flight-recorder event.  `Copy` only — precision values are
/// integer milli-bits (`4500` = 4.500 bits) so recording never
/// allocates or formats.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EventKind {
    // -- request lifecycle (pid = PID_REQUESTS, tid = request id) ------
    /// Admission allocated a slot: target precision + queue delay.
    Admit { id: u64, target_mb: u32, queue_us: u64 },
    /// Admission rejected the request (`capacity` = retryable 503 shape).
    Reject { id: u64, capacity: bool },
    /// One chunked-prefill dispatch (`pos` = positions ingested so far).
    PrefillChunk { id: u64, chunk: u32, pos: u32 },
    /// First streamed token (TTFT stamp).
    FirstToken { id: u64, ttft_us: u64 },
    /// Terminal completion: output tokens + effective milli-bits.
    Done { id: u64, tokens: u32, eff_mb: u32 },

    // -- precision decisions (pid = PID_PRECISION, tid = request id) ---
    /// Selector epoch re-assignment for one request: old → new target
    /// milli-bits, per-layer bit flips, effective-bits delta
    /// (milli-bits, signed).  Recorded for every active request at
    /// every re-selection epoch — `from_mb == to_mb` means the epoch
    /// kept the assignment.
    Reselect { id: u64, from_mb: u32, to_mb: u32, layers_changed: u32, eff_delta_mb: i32 },
    /// `downshift_for_pressure` engaged at admission: wanted → granted
    /// milli-bits at `pressure_pct`% pool pressure.
    PressureDownshift { id: u64, want_mb: u32, got_mb: u32, pressure_pct: u8 },
    /// The speculative-γ controller changed draft length for a request.
    GammaChange { id: u64, gamma: u8 },
    /// A `swap_bits` delta-rebind (engine reconfigure): stacks rebuilt,
    /// layer assignments changed, selector buffers re-uploaded.
    SwapBits { stacks: u32, layers: u32, uploads: u32 },

    // -- KV events (pid = PID_PRECISION, tid = request id) -------------
    /// KV tier migration (tier sizes in slots).
    KvMigrate { id: u64, from_tier: u32, to_tier: u32 },
    /// Shared-prefix cache hit: prefill positions skipped.
    PrefixHit { id: u64, saved_tokens: u32 },
    /// Prefix-cache entries dropped (LRU eviction or tag invalidation).
    PrefixEvict { entries: u32, invalidation: bool },

    // -- fleet events (pid = PID_FLEET, tid = replica id) --------------
    /// Router class-routed a request to a replica.
    Route { id: u64, replica: u32, premium: bool },
    /// Work stealing moved a backlogged request between replicas.
    Steal { id: u64, from: u32, to: u32 },
    /// Router forwarded a routed request to its replica thread.
    Forward { id: u64, replica: u32 },
    /// Drain began for a dead/wedged replica (`inflight` requests
    /// surfaced as retryable rejects, `backlog` re-routed).
    Drain { replica: u32, inflight: u32, backlog: u32 },
    /// The drained replica respawned.
    Respawn { replica: u32 },
    /// A replica reported ready; `us` is its spawn→ready wall time
    /// (runtime init + engine load + TPOT calibration), so drain→
    /// respawn→cold-start spans are readable off the fleet track.
    ColdStart { replica: u32, us: u64 },
}

impl EventKind {
    /// Chrome-trace event name.
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Admit { .. } => "admit",
            EventKind::Reject { .. } => "reject",
            EventKind::PrefillChunk { .. } => "prefill_chunk",
            EventKind::FirstToken { .. } => "first_token",
            EventKind::Done { .. } => "done",
            EventKind::Reselect { .. } => "reselect",
            EventKind::PressureDownshift { .. } => "pressure_downshift",
            EventKind::GammaChange { .. } => "gamma_change",
            EventKind::SwapBits { .. } => "swap_bits",
            EventKind::KvMigrate { .. } => "kv_migrate",
            EventKind::PrefixHit { .. } => "prefix_hit",
            EventKind::PrefixEvict { .. } => "prefix_evict",
            EventKind::Route { .. } => "route",
            EventKind::Steal { .. } => "steal",
            EventKind::Forward { .. } => "forward",
            EventKind::Drain { .. } => "drain",
            EventKind::Respawn { .. } => "respawn",
            EventKind::ColdStart { .. } => "cold_start",
        }
    }

    /// Chrome-trace (pid, tid) track assignment: requests and precision
    /// decisions get one track per request id; fleet events one track
    /// per replica id.
    pub fn track(&self) -> (u64, u64) {
        match *self {
            EventKind::Admit { id, .. }
            | EventKind::Reject { id, .. }
            | EventKind::PrefillChunk { id, .. }
            | EventKind::FirstToken { id, .. }
            | EventKind::Done { id, .. } => (PID_REQUESTS, id),
            EventKind::Reselect { id, .. }
            | EventKind::PressureDownshift { id, .. }
            | EventKind::GammaChange { id, .. }
            | EventKind::KvMigrate { id, .. }
            | EventKind::PrefixHit { id, .. } => (PID_PRECISION, id),
            EventKind::SwapBits { .. } | EventKind::PrefixEvict { .. } => (PID_PRECISION, 0),
            EventKind::Route { replica, .. } => (PID_FLEET, replica as u64),
            EventKind::Steal { from, .. } => (PID_FLEET, from as u64),
            EventKind::Forward { replica, .. } => (PID_FLEET, replica as u64),
            EventKind::Drain { replica, .. } => (PID_FLEET, replica as u64),
            EventKind::Respawn { replica } => (PID_FLEET, replica as u64),
            EventKind::ColdStart { replica, .. } => (PID_FLEET, replica as u64),
        }
    }

    /// Chrome-trace `args` payload (milli-bit fields surfaced as bits).
    fn args(&self) -> Json {
        let bits = |mb: u32| mb as f64 / 1000.0;
        let mut a = Json::obj();
        match *self {
            EventKind::Admit { id, target_mb, queue_us } => {
                a.set("id", id as i64)
                    .set("target_bits", bits(target_mb))
                    .set("queue_us", queue_us as i64);
            }
            EventKind::Reject { id, capacity } => {
                a.set("id", id as i64).set("capacity", capacity);
            }
            EventKind::PrefillChunk { id, chunk, pos } => {
                a.set("id", id as i64).set("chunk", chunk as i64).set("pos", pos as i64);
            }
            EventKind::FirstToken { id, ttft_us } => {
                a.set("id", id as i64).set("ttft_us", ttft_us as i64);
            }
            EventKind::Done { id, tokens, eff_mb } => {
                a.set("id", id as i64)
                    .set("tokens", tokens as i64)
                    .set("eff_bits", bits(eff_mb));
            }
            EventKind::Reselect { id, from_mb, to_mb, layers_changed, eff_delta_mb } => {
                a.set("id", id as i64)
                    .set("from_bits", bits(from_mb))
                    .set("to_bits", bits(to_mb))
                    .set("layers_changed", layers_changed as i64)
                    .set("eff_bits_delta", eff_delta_mb as f64 / 1000.0);
            }
            EventKind::PressureDownshift { id, want_mb, got_mb, pressure_pct } => {
                a.set("id", id as i64)
                    .set("want_bits", bits(want_mb))
                    .set("got_bits", bits(got_mb))
                    .set("pressure_pct", pressure_pct as i64);
            }
            EventKind::GammaChange { id, gamma } => {
                a.set("id", id as i64).set("gamma", gamma as i64);
            }
            EventKind::SwapBits { stacks, layers, uploads } => {
                a.set("stacks_rebuilt", stacks as i64)
                    .set("layers_changed", layers as i64)
                    .set("selector_uploads", uploads as i64);
            }
            EventKind::KvMigrate { id, from_tier, to_tier } => {
                a.set("id", id as i64)
                    .set("from_tier", from_tier as i64)
                    .set("to_tier", to_tier as i64);
            }
            EventKind::PrefixHit { id, saved_tokens } => {
                a.set("id", id as i64).set("saved_tokens", saved_tokens as i64);
            }
            EventKind::PrefixEvict { entries, invalidation } => {
                a.set("entries", entries as i64).set("invalidation", invalidation);
            }
            EventKind::Route { id, replica, premium } => {
                a.set("id", id as i64)
                    .set("replica", replica as i64)
                    .set("premium", premium);
            }
            EventKind::Steal { id, from, to } => {
                a.set("id", id as i64).set("from", from as i64).set("to", to as i64);
            }
            EventKind::Forward { id, replica } => {
                a.set("id", id as i64).set("replica", replica as i64);
            }
            EventKind::Drain { replica, inflight, backlog } => {
                a.set("replica", replica as i64)
                    .set("inflight", inflight as i64)
                    .set("backlog", backlog as i64);
            }
            EventKind::Respawn { replica } => {
                a.set("replica", replica as i64);
            }
            EventKind::ColdStart { replica, us } => {
                a.set("replica", replica as i64)
                    .set("cold_start_ms", us as f64 / 1e3);
            }
        }
        a
    }
}

/// One recorded event: microseconds since the tracer's epoch + payload.
#[derive(Debug, Clone, Copy)]
pub struct TraceEvent {
    pub t_us: u64,
    pub kind: EventKind,
}

/// Fixed-capacity overwrite-oldest buffer with an exact drop counter.
struct Ring {
    cap: usize,
    buf: Vec<TraceEvent>,
    /// Oldest element once saturated (`buf.len() == cap`).
    head: usize,
    dropped: u64,
}

impl Ring {
    fn new(cap: usize) -> Ring {
        Ring { cap: cap.max(1), buf: Vec::new(), head: 0, dropped: 0 }
    }

    fn push(&mut self, ev: TraceEvent) {
        if self.buf.len() < self.cap {
            self.buf.push(ev);
        } else {
            self.buf[self.head] = ev;
            self.head = (self.head + 1) % self.cap;
            self.dropped += 1;
        }
    }

    /// Oldest-first copy of the live window.
    fn ordered(&self) -> Vec<TraceEvent> {
        let mut out = Vec::with_capacity(self.buf.len());
        out.extend_from_slice(&self.buf[self.head..]);
        out.extend_from_slice(&self.buf[..self.head]);
        out
    }

    fn clear(&mut self) {
        self.buf.clear();
        self.head = 0;
        self.dropped = 0;
    }
}

struct Shared {
    /// Distinguishes tracers in the per-thread registry (a thread may
    /// record into several tracers over its lifetime — tests do).
    id: u64,
    enabled: AtomicBool,
    epoch: Instant,
    cap_per_thread: usize,
    rings: Mutex<Vec<Arc<Mutex<Ring>>>>,
}

thread_local! {
    /// This thread's rings, keyed by tracer id (linear scan: a thread
    /// records into one or two tracers in practice).
    static LOCAL_RINGS: RefCell<Vec<(u64, Arc<Mutex<Ring>>)>> = RefCell::new(Vec::new());
}

static NEXT_TRACER_ID: AtomicU64 = AtomicU64::new(1);

/// The flight recorder.  Cloning shares the same recorder (`Arc`
/// inside); [`global`] returns the process-wide instance the serving
/// stack records into.
#[derive(Clone)]
pub struct Tracer {
    shared: Arc<Shared>,
}

impl Tracer {
    /// A fresh recorder with `cap_per_thread` events per recording
    /// thread, initially disabled.
    pub fn new(cap_per_thread: usize) -> Tracer {
        Tracer {
            shared: Arc::new(Shared {
                id: NEXT_TRACER_ID.fetch_add(1, Ordering::Relaxed),
                enabled: AtomicBool::new(false),
                epoch: Instant::now(),
                cap_per_thread,
                rings: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn enabled(&self) -> bool {
        self.shared.enabled.load(Ordering::Relaxed)
    }

    pub fn set_enabled(&self, on: bool) {
        self.shared.enabled.store(on, Ordering::Relaxed);
    }

    /// Record one event.  Disabled: one relaxed load + branch.  Enabled:
    /// a timestamp, an uncontended lock on this thread's own ring, one
    /// slot write — no allocation once the ring is warm.
    #[inline]
    pub fn record(&self, kind: EventKind) {
        if !self.shared.enabled.load(Ordering::Relaxed) {
            return;
        }
        self.record_always(kind);
    }

    #[inline(never)]
    fn record_always(&self, kind: EventKind) {
        let t_us = self.shared.epoch.elapsed().as_micros() as u64;
        let ring = self.local_ring();
        ring.lock().unwrap().push(TraceEvent { t_us, kind });
    }

    fn local_ring(&self) -> Arc<Mutex<Ring>> {
        LOCAL_RINGS.with(|cell| {
            let mut local = cell.borrow_mut();
            if let Some((_, r)) = local.iter().find(|(id, _)| *id == self.shared.id) {
                return r.clone();
            }
            let r = Arc::new(Mutex::new(Ring::new(self.shared.cap_per_thread)));
            self.shared.rings.lock().unwrap().push(r.clone());
            local.push((self.shared.id, r.clone()));
            r
        })
    }

    /// Merge every thread's ring into one timestamp-ordered snapshot
    /// without clearing anything (`GET /trace` uses this).
    pub fn snapshot(&self) -> TraceSnapshot {
        self.collect(false)
    }

    /// Like [`Tracer::snapshot`], but clears the rings and drop
    /// counters (one-shot export, e.g. `--trace-out` at shutdown).
    pub fn drain(&self) -> TraceSnapshot {
        self.collect(true)
    }

    fn collect(&self, clear: bool) -> TraceSnapshot {
        let rings = self.shared.rings.lock().unwrap();
        let mut events = Vec::new();
        let mut dropped = 0u64;
        for ring in rings.iter() {
            let mut r = ring.lock().unwrap();
            events.extend(r.ordered());
            dropped += r.dropped;
            if clear {
                r.clear();
            }
        }
        // Stable sort: per-ring order is preserved among equal stamps.
        events.sort_by_key(|e| e.t_us);
        TraceSnapshot { events, dropped }
    }
}

/// A merged, timestamp-ordered view of the recorder.
#[derive(Debug)]
pub struct TraceSnapshot {
    /// Events oldest-first (globally sorted by `t_us`).
    pub events: Vec<TraceEvent>,
    /// Events overwritten before this snapshot, summed over rings —
    /// exact, so saturation is visible rather than silent.
    pub dropped: u64,
}

impl TraceSnapshot {
    /// Chrome trace-event JSON (the `{"traceEvents": [...]}` object
    /// form): instant events (`ph:"i"`, thread-scoped) on one track per
    /// request / replica / precision stream, with `ph:"M"` metadata
    /// naming the three process groups.  Loads in Perfetto and
    /// `chrome://tracing`; round-trips through [`crate::util::json`].
    pub fn chrome_json(&self) -> Json {
        let mut evs: Vec<Json> = Vec::with_capacity(self.events.len() + 3);
        for (pid, name) in [
            (PID_REQUESTS, "requests"),
            (PID_FLEET, "replicas"),
            (PID_PRECISION, "precision"),
        ] {
            let mut args = Json::obj();
            args.set("name", name);
            let mut m = Json::obj();
            m.set("name", "process_name")
                .set("ph", "M")
                .set("pid", pid as i64)
                .set("tid", 0i64)
                .set("args", args);
            evs.push(m);
        }
        for e in &self.events {
            let (pid, tid) = e.kind.track();
            let mut j = Json::obj();
            j.set("name", e.kind.name())
                .set("ph", "i")
                .set("s", "t")
                .set("ts", e.t_us as i64)
                .set("pid", pid as i64)
                .set("tid", tid as i64)
                .set("args", e.kind.args());
            evs.push(j);
        }
        let mut top = Json::obj();
        top.set("traceEvents", Json::Arr(evs))
            .set("dropped", self.dropped as i64);
        top
    }
}

/// The process-wide flight recorder every serving component records
/// into.  Disabled unless `DPLLM_TRACE` is set (to anything but `0`) or
/// a caller (CLI `--trace-out`, tests) enables it explicitly.
pub fn global() -> &'static Tracer {
    static GLOBAL: OnceLock<Tracer> = OnceLock::new();
    GLOBAL.get_or_init(|| {
        let t = Tracer::new(DEFAULT_RING_CAP);
        if std::env::var("DPLLM_TRACE").map(|v| v != "0" && !v.is_empty()).unwrap_or(false) {
            t.set_enabled(true);
        }
        t
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tick(id: u64) -> EventKind {
        EventKind::FirstToken { id, ttft_us: id }
    }

    #[test]
    fn disabled_tracer_records_nothing() {
        let t = Tracer::new(8);
        t.record(tick(1));
        assert!(t.snapshot().events.is_empty());
        assert_eq!(t.snapshot().dropped, 0);
    }

    #[test]
    fn ring_wraparound_keeps_newest_and_counts_drops_exactly() {
        let t = Tracer::new(8);
        t.set_enabled(true);
        for i in 0..11u64 {
            t.record(tick(i));
        }
        let s = t.snapshot();
        assert_eq!(s.events.len(), 8, "ring holds exactly cap events");
        assert_eq!(s.dropped, 3, "drop counter is exact");
        // The survivors are the NEWEST 8 (overwrite-oldest), in order.
        let ids: Vec<u64> = s
            .events
            .iter()
            .map(|e| match e.kind {
                EventKind::FirstToken { id, .. } => id,
                _ => unreachable!(),
            })
            .collect();
        assert_eq!(ids, (3..11).collect::<Vec<u64>>());
        // Drain clears both the window and the drop counter.
        let d = t.drain();
        assert_eq!(d.events.len(), 8);
        assert_eq!(t.snapshot().events.len(), 0);
        assert_eq!(t.snapshot().dropped, 0);
    }

    #[test]
    fn cross_thread_merge_is_timestamp_ordered_and_lossless() {
        let t = Tracer::new(1024);
        t.set_enabled(true);
        let mut handles = Vec::new();
        for thread in 0..4u64 {
            let t = t.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..100u64 {
                    t.record(EventKind::FirstToken { id: thread, ttft_us: i });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let s = t.snapshot();
        assert_eq!(s.events.len(), 400);
        assert_eq!(s.dropped, 0);
        // Global merge is non-decreasing in time, and each per-request
        // track (= per-thread here) kept its own order.
        for w in s.events.windows(2) {
            assert!(w[0].t_us <= w[1].t_us, "merge not time-ordered");
        }
        for thread in 0..4u64 {
            let seq: Vec<u64> = s
                .events
                .iter()
                .filter_map(|e| match e.kind {
                    EventKind::FirstToken { id, ttft_us } if id == thread => Some(ttft_us),
                    _ => None,
                })
                .collect();
            assert_eq!(seq, (0..100).collect::<Vec<u64>>(), "track {thread} reordered");
        }
    }

    #[test]
    fn chrome_json_round_trips_through_util_json() {
        let t = Tracer::new(64);
        t.set_enabled(true);
        t.record(EventKind::Admit { id: 7, target_mb: 4500, queue_us: 120 });
        t.record(EventKind::Reselect {
            id: 7,
            from_mb: 4500,
            to_mb: 3500,
            layers_changed: 9,
            eff_delta_mb: -1000,
        });
        t.record(EventKind::Drain { replica: 2, inflight: 3, backlog: 1 });
        t.record(EventKind::Respawn { replica: 2 });
        t.record(EventKind::Done { id: 7, tokens: 16, eff_mb: 3600 });
        let j = t.snapshot().chrome_json();
        let parsed = Json::parse(&j.dump()).expect("chrome trace JSON parses back");
        let evs = parsed.get("traceEvents").unwrap().as_arr().unwrap();
        // 3 metadata records + 5 instants.
        assert_eq!(evs.len(), 8);
        assert_eq!(evs[0].str_of("ph").unwrap(), "M");
        let admit = evs.iter().find(|e| e.str_of("name").as_deref() == Ok("admit")).unwrap();
        assert_eq!(admit.str_of("ph").unwrap(), "i");
        assert_eq!(admit.f64_of("pid").unwrap(), PID_REQUESTS as f64);
        assert_eq!(admit.f64_of("tid").unwrap(), 7.0);
        let args = admit.get("args").unwrap();
        assert!((args.f64_of("target_bits").unwrap() - 4.5).abs() < 1e-9);
        let resel = evs.iter().find(|e| e.str_of("name").as_deref() == Ok("reselect")).unwrap();
        assert_eq!(resel.f64_of("pid").unwrap(), PID_PRECISION as f64);
        let args = resel.get("args").unwrap();
        assert!((args.f64_of("eff_bits_delta").unwrap() + 1.0).abs() < 1e-9);
        assert_eq!(parsed.f64_of("dropped").unwrap(), 0.0);
    }
}
