//! Structured leveled logging: the [`dpllm_log!`](crate::dpllm_log)
//! macro + `DPLLM_LOG` env filtering (DESIGN.md §Observability).
//!
//! Every former bare `eprintln!` in the serving stack now goes through
//! `dpllm_log!(level, component, fmt, …)`, which renders as
//! `[LEVEL component] message` on stderr and is filtered by the
//! `DPLLM_LOG` environment variable:
//!
//! - `DPLLM_LOG=warn` — global minimum level (default `info`)
//! - `DPLLM_LOG=warn,router=debug,core=trace` — per-component
//!   overrides on top of the global minimum
//! - levels, most to least severe: `error`, `warn`, `info`, `debug`,
//!   `trace`
//!
//! The filter parses once (first log call) and the enabled check is a
//! cheap comparison, so log statements can sit on serving paths.

use std::sync::OnceLock;

/// Log severity, most severe first (`Error < Warn` in ordering terms:
/// a level is emitted when `level <= minimum`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN",
            Level::Info => "INFO",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }

    fn parse(s: &str) -> Option<Level> {
        match s.trim().to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            "trace" => Some(Level::Trace),
            _ => None,
        }
    }
}

/// Parsed `DPLLM_LOG` filter: a global minimum + per-component
/// overrides.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LogFilter {
    default: Level,
    overrides: Vec<(String, Level)>,
}

impl LogFilter {
    /// Parse a `DPLLM_LOG`-shaped spec (`"warn,router=debug"`).
    /// Unknown tokens are ignored rather than fatal — a typo in an env
    /// var must not take the server down.
    pub fn parse(spec: &str) -> LogFilter {
        let mut f = LogFilter { default: Level::Info, overrides: Vec::new() };
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            match part.split_once('=') {
                Some((comp, lvl)) => {
                    if let Some(l) = Level::parse(lvl) {
                        f.overrides.push((comp.trim().to_string(), l));
                    }
                }
                None => {
                    if let Some(l) = Level::parse(part) {
                        f.default = l;
                    }
                }
            }
        }
        f
    }

    /// Minimum level for one component.
    pub fn min_level(&self, component: &str) -> Level {
        self.overrides
            .iter()
            .find(|(c, _)| c == component)
            .map(|&(_, l)| l)
            .unwrap_or(self.default)
    }

    pub fn enabled(&self, level: Level, component: &str) -> bool {
        level <= self.min_level(component)
    }
}

fn filter() -> &'static LogFilter {
    static FILTER: OnceLock<LogFilter> = OnceLock::new();
    FILTER.get_or_init(|| LogFilter::parse(&std::env::var("DPLLM_LOG").unwrap_or_default()))
}

/// Is a `(level, component)` pair emitted under the current filter?
/// (Called by the macro before formatting, so disabled statements never
/// format their arguments.)
pub fn enabled(level: Level, component: &str) -> bool {
    filter().enabled(level, component)
}

/// Emit one formatted record (the macro's backend).
pub fn log(level: Level, component: &str, args: std::fmt::Arguments<'_>) {
    eprintln!("[{} {}] {}", level.tag(), component, args);
}

/// Structured leveled logging: `dpllm_log!(Info, "server", "listening
/// on {addr}")`.  Filtered by `DPLLM_LOG` (see
/// [`obs::log`](crate::obs::log)); arguments are not formatted when the
/// statement is filtered out.
#[macro_export]
macro_rules! dpllm_log {
    ($lvl:ident, $comp:expr, $($arg:tt)*) => {
        if $crate::obs::log::enabled($crate::obs::log::Level::$lvl, $comp) {
            $crate::obs::log::log(
                $crate::obs::log::Level::$lvl,
                $comp,
                format_args!($($arg)*),
            );
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_filter_is_info() {
        let f = LogFilter::parse("");
        assert!(f.enabled(Level::Error, "core"));
        assert!(f.enabled(Level::Info, "core"));
        assert!(!f.enabled(Level::Debug, "core"));
        assert!(!f.enabled(Level::Trace, "core"));
    }

    #[test]
    fn global_level_and_component_overrides() {
        let f = LogFilter::parse("warn,router=debug, core = trace");
        assert!(!f.enabled(Level::Info, "server"), "global floor is warn");
        assert!(f.enabled(Level::Warn, "server"));
        assert!(f.enabled(Level::Debug, "router"));
        assert!(!f.enabled(Level::Trace, "router"));
        assert!(f.enabled(Level::Trace, "core"), "whitespace-tolerant override");
    }

    #[test]
    fn junk_tokens_are_ignored_not_fatal() {
        let f = LogFilter::parse("blurp,router=notalevel,=,debug");
        assert_eq!(f.min_level("router"), Level::Debug, "global debug survives junk");
    }

    #[test]
    fn macro_compiles_against_the_filter() {
        // Smoke: both filtered and emitted paths type-check and run.
        crate::dpllm_log!(Error, "obs-test", "answer={}", 42);
        crate::dpllm_log!(Trace, "obs-test", "filtered out {}", "normally");
    }
}
