//! `dpllm` — CLI entry point for the DP-LLM coordinator.
//!
//! Subcommands are registered in `cli::run`; run `dpllm help` for the list.

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    std::process::exit(match dp_llm::cli_main(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    });
}
