//! Shared plumbing for the table/figure bench harnesses (criterion is not
//! in the offline cache; each bench is a `harness = false` binary that
//! prints the paper-style rows and writes them under `results/`).

use std::sync::Arc;

use anyhow::{Context, Result};

use crate::evalharness::{self, Method, PplResult};
use crate::model::{artifacts_available, Manifest, ModelAssets};
use crate::runtime::decode::EstMode;
use crate::runtime::Runtime;
use crate::util::stats::format_table;

/// Paper-table method lineup, in row order.
pub fn methods_for_target(target: f64) -> Vec<Method> {
    vec![
        Method::Static { method: "llm_mq".into(), target },
        Method::Static { method: "hawq_v2".into(), target },
        Method::Dpllm { tag: format!("{target:.2}") },
    ]
}

pub fn targets_for_budget(budget: u32) -> Vec<f64> {
    match budget {
        b if b >= 6 => vec![3.5, 4.0, 4.5, 5.0, 5.5],
        5 => vec![3.25, 3.5, 3.75, 4.0, 4.25, 4.5, 4.75],
        _ => vec![3.25, 3.5, 3.75],
    }
}

/// Abort politely when `make artifacts` hasn't run (benches must never
/// fail the build on a fresh checkout).
pub fn require_artifacts(bench: &str) -> bool {
    if artifacts_available() {
        return true;
    }
    println!("[{bench}] artifacts not built — run `make artifacts` first; skipping");
    false
}

pub fn note_missing(bench: &str, what: &str) {
    println!("[{bench}] {what} not found — run `make artifacts-extended`; skipping");
}

/// One perplexity cell, or None when that config's artifacts are missing.
pub fn ppl_cell(rt: &Arc<Runtime>, assets: &ModelAssets, manifest: &Manifest,
                budget: u32, method: &Method, stream: &[u16], mode: EstMode)
                -> Option<PplResult> {
    let session = evalharness::build_session(rt, assets, manifest, budget, method).ok()?;
    evalharness::perplexity(
        &session, stream, evalharness::eval_chunk_default(),
        evalharness::eval_tokens_default(), mode)
        .ok()
}

/// Write a rendered table to stdout and `results/<name>.txt`.
pub fn emit(name: &str, title: &str, header: &[&str], rows: &[Vec<String>]) {
    let table = format_table(header, rows);
    println!("== {title} ==\n{table}");
    let _ = std::fs::create_dir_all("results");
    let _ = std::fs::write(format!("results/{name}.txt"),
                           format!("{title}\n{table}"));
}

/// Standard harness preamble: runtime + manifest.
pub fn setup() -> Result<(Arc<Runtime>, Manifest)> {
    let rt = Arc::new(Runtime::new().context("PJRT runtime")?);
    let manifest = Manifest::load()?;
    Ok((rt, manifest))
}

pub fn fmt_ppl(p: Option<&PplResult>) -> String {
    match p {
        // 4 decimals: at sandbox scale the per-channel-quantized tiny
        // models lose only ~1-2% ppl at 3 bits, so the inter-method gaps
        // sit in the 3rd-4th decimal (see EXPERIMENTS.md — Table 1 note).
        Some(r) => format!("{:.4}", r.ppl),
        None => "-".into(),
    }
}

/// The two headline models (paper: Llama-3-8B / Phi-3-Medium analogs).
pub fn headline_models() -> Vec<&'static str> {
    vec!["dpl-tiny", "dpl-small"]
}

pub fn model_available(name: &str) -> bool {
    ModelAssets::load(name).is_ok()
}
