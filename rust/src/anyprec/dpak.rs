//! DPAK — the versioned single-file container for the any-precision
//! weight store (DESIGN.md §Artifact).
//!
//! ```text
//! offset 0   magic  b"DPAK"
//!        4   u32 LE format version (currently 1)
//!        8   u64 LE manifest byte length
//!       16   UTF-8 JSON manifest
//!        ...zero padding to a 64-byte boundary...
//!            sections, each 64-byte aligned, zero-padded between
//! ```
//!
//! Sections are laid out **plane-major**: every group's bitplane 0
//! (MSB), then every group's bitplane 1, … then the LUTs by ascending
//! bitwidth.  With nested-prefix codes (PR 2: `code_{b+1} = code_b << 1
//! | bit_b`) this makes higher bitwidths *pure appended deltas*: the
//! planes a `max_bits` tier needs are a prefix of the plane region
//! (the dominant bytes), and the (small) LUT region is likewise
//! ordered ascending — a node touches only what its precision tier
//! serves.
//!
//! The manifest (wolfpack-style: name/version/arch + per-entry offsets
//! and digests) records for every section its absolute byte offset,
//! length, and CRC-32 digest, plus per-layer digests inside each plane
//! section (partial-fetch validation).  `version` is the content
//! identity: the CRC-32 of all section digest strings in canonical
//! order — two containers with identical weights get identical
//! versions no matter when or where they were packed.  The same bytes
//! are produced by `python/compile/pack.py`; the cross-language digest
//! contract is pinned by `util::digest` known-vector tests.
//!
//! Loading ([`load`]) verifies the manifest geometry and every mapped
//! section digest, then hands out plane/LUT ranges **borrowed from one
//! read-only mmap** — zero plane-byte copies, one physical mapping
//! shared by every replica view.  All failure modes are typed
//! [`DpakError`]s: fleet boot refuses cleanly instead of panicking.

use std::collections::BTreeMap;
use std::fmt;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::util::digest::{crc32, digest_str};
use crate::util::json::Json;
use crate::util::mmap::Mmap;

use super::{
    AnyPrecStore, GroupStore, LoadStats, LutBytes, PlaneBytes, GROUPS, MAX_BITS,
    MIN_BITS,
};

pub const DPAK_MAGIC: [u8; 4] = *b"DPAK";
pub const DPAK_FORMAT_VERSION: u32 = 1;
/// Section alignment: cache-line / SIMD friendly, and guarantees the
/// f32 LUT reinterpret is aligned on any page-aligned mapping.
pub const DPAK_ALIGN: usize = 64;

/// Identity of a loaded DPAK container (the serve-time version gate
/// compares this against what the AOT manifest recorded at pack time).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct DpakMeta {
    pub model: String,
    /// Content version: `crc32:` over all section digests.
    pub version: String,
    pub format_version: u32,
    /// The precision ceiling this *view* resides at (≤ the container's).
    pub max_bits: u8,
}

/// Why a DPAK container was refused.  Typed so fleet boot / serve can
/// branch (and tests can pin) the exact failure, and `Display` gives the
/// operator the artifact-level story.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DpakError {
    BadMagic,
    UnsupportedFormatVersion(u32),
    /// The file ends before `what` does.
    Truncated { what: String, need: usize, have: usize },
    /// The manifest JSON is missing/malformed/inconsistent.
    Manifest(String),
    /// A section's recorded offset/length disagrees with the geometry
    /// the manifest itself declares.
    OffsetMismatch { section: String, detail: String },
    /// Stored bytes do not hash to the recorded digest (corruption).
    DigestMismatch { section: String, want: String, got: String },
    /// Serve-time identity check failed (wrong model or stale version).
    VersionGate { field: String, want: String, got: String },
}

impl fmt::Display for DpakError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DpakError::BadMagic => write!(f, "not a DPAK container (bad magic)"),
            DpakError::UnsupportedFormatVersion(v) => {
                write!(f, "DPAK format version {v} not supported (reader speaks \
                           {DPAK_FORMAT_VERSION})")
            }
            DpakError::Truncated { what, need, have } => {
                write!(f, "truncated container: {what} needs {need} bytes, \
                           file has {have}")
            }
            DpakError::Manifest(d) => write!(f, "bad DPAK manifest: {d}"),
            DpakError::OffsetMismatch { section, detail } => {
                write!(f, "section {section}: offset/length mismatch — {detail}")
            }
            DpakError::DigestMismatch { section, want, got } => {
                write!(f, "section {section}: digest mismatch (manifest {want}, \
                           stored bytes {got}) — container is corrupt")
            }
            DpakError::VersionGate { field, want, got } => {
                write!(f, "version gate refused: {field} is '{got}', deployment \
                           expects '{want}'")
            }
        }
    }
}

impl std::error::Error for DpakError {}

fn align_up(x: usize) -> usize {
    (x + DPAK_ALIGN - 1) / DPAK_ALIGN * DPAK_ALIGN
}

// ---------------------------------------------------------------------------
// Writer
// ---------------------------------------------------------------------------

struct Section {
    /// `plane{p}/{group}` or `lut{b}/{group}` — error/manifest naming.
    kind: SectionKind,
    payload: Vec<u8>,
    digest: String,
    /// Per-layer digests (plane sections only).
    layers: Vec<String>,
    off: usize,
}

enum SectionKind {
    Plane { group: &'static str, p: usize },
    Lut { group: &'static str, bits: u8 },
}

/// Pack a (full-precision) store into a DPAK container at `path`.
/// Returns the identity the container now carries.
pub fn write(store: &AnyPrecStore, model: &str, path: &str) -> Result<DpakMeta> {
    if store.max_bits() != MAX_BITS {
        bail!("pack requires a full-precision store (max_bits {}), got {}",
              MAX_BITS, store.max_bits());
    }
    for g in GROUPS {
        store.group(g).with_context(|| "pack: store missing a group")?;
    }
    // Canonical section order: plane-major across groups, then LUTs by
    // ascending bitwidth — the tier-slice prefix property.
    let mut sections: Vec<Section> = Vec::new();
    for p in 0..MAX_BITS as usize {
        for g in GROUPS {
            let gs = store.group(g)?;
            let payload = gs.planes[p].as_slice().to_vec();
            let layer_bytes = gs.out_dim * gs.in_dim / 8;
            let layers = (0..gs.n_layers)
                .map(|l| digest_str(&payload[l * layer_bytes..(l + 1) * layer_bytes]))
                .collect();
            let digest = digest_str(&payload);
            sections.push(Section {
                kind: SectionKind::Plane { group: g, p },
                payload, digest, layers, off: 0,
            });
        }
    }
    for b in MIN_BITS..=MAX_BITS {
        for g in GROUPS {
            let gs = store.group(g)?;
            let payload: Vec<u8> =
                gs.lut(b)?.iter().flat_map(|x| x.to_le_bytes()).collect();
            let digest = digest_str(&payload);
            sections.push(Section {
                kind: SectionKind::Lut { group: g, bits: b },
                payload, digest, layers: Vec::new(), off: 0,
            });
        }
    }
    // Content version: digest of the section digests in canonical order.
    let mut ver = String::new();
    for s in &sections {
        ver.push_str(&s.digest);
    }
    let version = format!("crc32:{:08x}", crc32(ver.as_bytes()));

    // Manifest length and section offsets depend on each other (offsets
    // are absolute and appear inside the manifest); iterate to a fixed
    // point, padding with trailing spaces if the render lands short.
    let mut mlen = 0usize;
    let manifest_bytes = loop {
        let data_start = align_up(16 + mlen);
        let mut off = data_start;
        for s in sections.iter_mut() {
            s.off = off;
            off = align_up(off + s.payload.len());
        }
        let rendered = render_manifest(store, model, &version, &sections).dump();
        if rendered.len() <= mlen {
            let mut bytes = rendered.into_bytes();
            bytes.resize(mlen, b' '); // Json::parse skips trailing ws
            break bytes;
        }
        mlen = rendered.len();
    };

    let data_start = align_up(16 + manifest_bytes.len());
    let end = sections
        .last()
        .map(|s| s.off + s.payload.len())
        .unwrap_or(data_start);
    let mut out = vec![0u8; end];
    out[0..4].copy_from_slice(&DPAK_MAGIC);
    out[4..8].copy_from_slice(&DPAK_FORMAT_VERSION.to_le_bytes());
    out[8..16].copy_from_slice(&(manifest_bytes.len() as u64).to_le_bytes());
    out[16..16 + manifest_bytes.len()].copy_from_slice(&manifest_bytes);
    for s in &sections {
        out[s.off..s.off + s.payload.len()].copy_from_slice(&s.payload);
    }
    std::fs::write(path, out).with_context(|| format!("writing {path}"))?;
    Ok(DpakMeta {
        model: model.to_string(),
        version,
        format_version: DPAK_FORMAT_VERSION,
        max_bits: MAX_BITS,
    })
}

fn render_manifest(store: &AnyPrecStore, model: &str, version: &str,
                   sections: &[Section]) -> Json {
    let mut groups = Json::obj();
    for g in GROUPS {
        let gs = store.group(g).expect("checked by write()");
        let mut planes = vec![Json::Null; MAX_BITS as usize];
        let mut luts = Json::obj();
        for s in sections {
            match &s.kind {
                SectionKind::Plane { group, p } if *group == g => {
                    let mut e = Json::obj();
                    e.set("off", s.off).set("len", s.payload.len());
                    e.set("digest", s.digest.as_str());
                    e.set("layers",
                          Json::Arr(s.layers.iter()
                              .map(|d| Json::Str(d.clone())).collect()));
                    planes[*p] = e;
                }
                SectionKind::Lut { group, bits } if *group == g => {
                    let mut e = Json::obj();
                    e.set("off", s.off).set("len", s.payload.len());
                    e.set("digest", s.digest.as_str());
                    luts.set(&bits.to_string(), e);
                }
                _ => {}
            }
        }
        let mut gj = Json::obj();
        gj.set("n_layers", gs.n_layers)
            .set("out", gs.out_dim)
            .set("in", gs.in_dim)
            .set("planes", Json::Arr(planes))
            .set("luts", luts);
        groups.set(g, gj);
    }
    let mut m = Json::obj();
    m.set("format", "dpak")
        .set("format_version", DPAK_FORMAT_VERSION as usize)
        .set("model", model)
        .set("version", version)
        .set("dtype", "f32")
        .set("min_bits", MIN_BITS as usize)
        .set("max_bits", MAX_BITS as usize)
        .set("groups", groups);
    m
}

// ---------------------------------------------------------------------------
// Loader
// ---------------------------------------------------------------------------

struct Parsed {
    map: Arc<Mmap>,
    manifest: Json,
    format_version: u32,
}

fn parse_container(path: &str) -> Result<Parsed> {
    let map = Arc::new(Mmap::open(path)?);
    let bytes: &[u8] = &map;
    if bytes.len() < 16 {
        return Err(DpakError::Truncated {
            what: "header".into(), need: 16, have: bytes.len(),
        }.into());
    }
    if bytes[0..4] != DPAK_MAGIC {
        return Err(DpakError::BadMagic.into());
    }
    let format_version =
        u32::from_le_bytes([bytes[4], bytes[5], bytes[6], bytes[7]]);
    if format_version != DPAK_FORMAT_VERSION {
        return Err(DpakError::UnsupportedFormatVersion(format_version).into());
    }
    let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
    if 16 + mlen > bytes.len() {
        return Err(DpakError::Truncated {
            what: "manifest".into(), need: 16 + mlen, have: bytes.len(),
        }.into());
    }
    let text = std::str::from_utf8(&bytes[16..16 + mlen])
        .map_err(|e| DpakError::Manifest(format!("manifest not utf-8: {e}")))?;
    let manifest = Json::parse(text)
        .map_err(|e| DpakError::Manifest(format!("manifest json: {e}")))?;
    if manifest.str_of("format").unwrap_or_default() != "dpak" {
        return Err(DpakError::Manifest("format field is not 'dpak'".into()).into());
    }
    Ok(Parsed { map, manifest, format_version })
}

/// One manifest section entry, bounds- and digest-checked against the
/// mapping.  Returns the validated (off, len).
fn checked_section(map: &Mmap, entry: &Json, name: &str,
                   want_len: usize) -> Result<(usize, usize)> {
    let off = entry
        .usize_of("off")
        .map_err(|e| DpakError::Manifest(format!("{name}: {e}")))?;
    let len = entry
        .usize_of("len")
        .map_err(|e| DpakError::Manifest(format!("{name}: {e}")))?;
    if len != want_len {
        return Err(DpakError::OffsetMismatch {
            section: name.into(),
            detail: format!("manifest length {len}, geometry wants {want_len}"),
        }.into());
    }
    if off % DPAK_ALIGN != 0 || off < 16 {
        return Err(DpakError::OffsetMismatch {
            section: name.into(),
            detail: format!("offset {off} not {DPAK_ALIGN}-byte aligned"),
        }.into());
    }
    if off + len > map.len() {
        return Err(DpakError::Truncated {
            what: format!("section {name}"), need: off + len, have: map.len(),
        }.into());
    }
    let want = entry
        .str_of("digest")
        .map_err(|e| DpakError::Manifest(format!("{name}: {e}")))?;
    let got = digest_str(&map[off..off + len]);
    if got != want {
        return Err(DpakError::DigestMismatch {
            section: name.into(), want, got,
        }.into());
    }
    Ok((off, len))
}

/// Validate and map a DPAK container, residing only the planes/LUTs a
/// `max_bits` precision tier needs.  Zero plane bytes are copied; every
/// resided section's digest is verified before the store is handed out.
pub fn load(path: &str, max_bits: u8) -> Result<AnyPrecStore> {
    if !(MIN_BITS..=MAX_BITS).contains(&max_bits) {
        bail!("load_slice max_bits {max_bits} out of range {MIN_BITS}..={MAX_BITS}");
    }
    if cfg!(target_endian = "big") {
        bail!("DPAK containers are little-endian; big-endian hosts unsupported");
    }
    let t0 = std::time::Instant::now();
    let parsed = parse_container(path).with_context(|| format!("loading {path}"))?;
    let Parsed { map, manifest, format_version } = parsed;
    let file_max: u8 = manifest.usize_of("max_bits").unwrap_or(MAX_BITS as usize) as u8;
    if max_bits > file_max {
        return Err(DpakError::Manifest(format!(
            "container holds {file_max} bits, slice wants {max_bits}"
        )).into());
    }
    let gobj = manifest
        .req("groups")
        .map_err(|e| DpakError::Manifest(e.to_string()))?;
    let mut groups = BTreeMap::new();
    let mut stats = LoadStats::default();
    for g in GROUPS {
        let gj = gobj
            .get(g)
            .ok_or_else(|| DpakError::Manifest(format!("missing group {g}")))?;
        let n_layers = gj.usize_of("n_layers")
            .map_err(|e| DpakError::Manifest(format!("{g}: {e}")))?;
        let out_dim = gj.usize_of("out")
            .map_err(|e| DpakError::Manifest(format!("{g}: {e}")))?;
        let in_dim = gj.usize_of("in")
            .map_err(|e| DpakError::Manifest(format!("{g}: {e}")))?;
        if in_dim % 8 != 0 || n_layers == 0 || out_dim == 0 || in_dim == 0 {
            return Err(DpakError::Manifest(format!(
                "group {g}: degenerate geometry [L={n_layers}, out={out_dim}, \
                 in={in_dim}]"
            )).into());
        }
        let parr = gj.req("planes")
            .and_then(|p| p.as_arr())
            .map_err(|e| DpakError::Manifest(format!("{g} planes: {e}")))?;
        if parr.len() != file_max as usize {
            return Err(DpakError::Manifest(format!(
                "group {g}: {} plane entries, container max_bits {file_max}",
                parr.len()
            )).into());
        }
        let plane_len = n_layers * out_dim * in_dim / 8;
        let mut planes = Vec::with_capacity(max_bits as usize);
        for (p, entry) in parr.iter().enumerate().take(max_bits as usize) {
            let name = format!("plane{p}/{g}");
            let (off, len) = checked_section(&map, entry, &name, plane_len)?;
            let layers = entry.req("layers").and_then(|l| l.as_arr())
                .map_err(|e| DpakError::Manifest(format!("{name}: {e}")))?;
            if layers.len() != n_layers {
                return Err(DpakError::Manifest(format!(
                    "{name}: {} layer digests, {n_layers} layers", layers.len()
                )).into());
            }
            stats.plane_bytes_mapped += len as u64;
            planes.push(PlaneBytes::Mapped { map: map.clone(), off, len });
        }
        let lobj = gj.req("luts")
            .map_err(|e| DpakError::Manifest(format!("{g}: {e}")))?;
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=max_bits {
            let name = format!("lut{b}/{g}");
            let entry = lobj.get(&b.to_string())
                .ok_or_else(|| DpakError::Manifest(format!("missing {name}")))?;
            let lut_len = n_layers * out_dim * (1usize << b) * 4;
            let (off, len) = checked_section(&map, entry, &name, lut_len)?;
            let base = map.as_ptr() as usize + off;
            if base % 4 == 0 {
                stats.lut_bytes_mapped += len as u64;
                luts.insert(b, LutBytes::Mapped { map: map.clone(), off, n: len / 4 });
            } else {
                // Owned-read fallback whose buffer landed unaligned:
                // copy this LUT rather than reinterpret misaligned f32s.
                let v: Vec<f32> = map[off..off + len]
                    .chunks_exact(4)
                    .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                    .collect();
                stats.lut_bytes_copied += len as u64;
                luts.insert(b, LutBytes::Owned(Arc::from(v)));
            }
        }
        let gs = GroupStore {
            planes, n_layers, out_dim, in_dim, luts, max_bits,
        };
        gs.validate().with_context(|| format!("group {g} of {path}"))?;
        groups.insert(g.to_string(), gs);
    }
    let meta = DpakMeta {
        model: manifest.str_of("model")
            .map_err(|e| DpakError::Manifest(e.to_string()))?,
        version: manifest.str_of("version")
            .map_err(|e| DpakError::Manifest(e.to_string()))?,
        format_version,
        max_bits,
    };
    stats.load_ms = t0.elapsed().as_secs_f64() * 1e3;
    stats.mapped = map.is_mapped();
    Ok(AnyPrecStore { groups, meta: Some(meta), map: Some(map), stats })
}

/// Serve-time identity check: the container must carry the expected
/// model name (and, when the AOT manifest recorded one at pack time, the
/// exact content version).  Refusal is the typed
/// [`DpakError::VersionGate`] — fleet boot stops before touching
/// devices, instead of serving stale or foreign weights.
pub fn check_version_gate(meta: &DpakMeta, model: &str,
                          expect_version: Option<&str>) -> Result<()> {
    if meta.model != model {
        return Err(DpakError::VersionGate {
            field: "model".into(),
            want: model.to_string(),
            got: meta.model.clone(),
        }.into());
    }
    if let Some(v) = expect_version {
        if meta.version != v {
            return Err(DpakError::VersionGate {
                field: "version".into(),
                want: v.to_string(),
                got: meta.version.clone(),
            }.into());
        }
    }
    Ok(())
}

/// Deep-inspect a container: verify EVERY section digest *and* the
/// per-layer digests inside each plane section, and return a summary
/// (the `dpllm inspect` subcommand).
pub fn inspect(path: &str) -> Result<Json> {
    let parsed = parse_container(path).with_context(|| format!("inspecting {path}"))?;
    let Parsed { map, manifest, format_version } = parsed;
    let file_max: u8 = manifest.usize_of("max_bits").unwrap_or(MAX_BITS as usize) as u8;
    let gobj = manifest.req("groups")
        .map_err(|e| DpakError::Manifest(e.to_string()))?;
    let mut groups_out = Json::obj();
    let mut n_sections = 0usize;
    let mut data_bytes = 0usize;
    for g in GROUPS {
        let gj = gobj.get(g)
            .ok_or_else(|| DpakError::Manifest(format!("missing group {g}")))?;
        let n_layers = gj.usize_of("n_layers")?;
        let out_dim = gj.usize_of("out")?;
        let in_dim = gj.usize_of("in")?;
        let plane_len = n_layers * out_dim * in_dim / 8;
        let layer_bytes = out_dim * in_dim / 8;
        let mut plane_bytes = 0usize;
        for (p, entry) in gj.req("planes")?.as_arr()?.iter().enumerate() {
            let name = format!("plane{p}/{g}");
            let (off, len) = checked_section(&map, entry, &name, plane_len)?;
            // Per-layer digests: the partial-fetch validation contract.
            let layers = entry.req("layers")?.as_arr()?;
            for (l, want) in layers.iter().enumerate() {
                let want = want.as_str()?;
                let lo = off + l * layer_bytes;
                let got = digest_str(&map[lo..lo + layer_bytes]);
                if got != want {
                    return Err(DpakError::DigestMismatch {
                        section: format!("{name} layer {l}"),
                        want: want.to_string(),
                        got,
                    }.into());
                }
            }
            plane_bytes += len;
            n_sections += 1;
        }
        let mut lut_bytes = 0usize;
        let lobj = gj.req("luts")?;
        for b in MIN_BITS..=file_max {
            let name = format!("lut{b}/{g}");
            let entry = lobj.get(&b.to_string())
                .ok_or_else(|| DpakError::Manifest(format!("missing {name}")))?;
            let lut_len = n_layers * out_dim * (1usize << b) * 4;
            let (_, len) = checked_section(&map, entry, &name, lut_len)?;
            lut_bytes += len;
            n_sections += 1;
        }
        let mut row = Json::obj();
        row.set("n_layers", n_layers).set("out", out_dim).set("in", in_dim)
            .set("plane_bytes", plane_bytes).set("lut_bytes", lut_bytes);
        groups_out.set(g, row);
        data_bytes += plane_bytes + lut_bytes;
    }
    let mut out = Json::obj();
    out.set("file", path)
        .set("file_bytes", map.len())
        .set("format_version", format_version as usize)
        .set("model", manifest.str_of("model")?)
        .set("version", manifest.str_of("version")?)
        .set("min_bits", manifest.usize_of("min_bits").unwrap_or(MIN_BITS as usize))
        .set("max_bits", file_max as usize)
        .set("sections", n_sections)
        .set("data_bytes", data_bytes)
        .set("groups", groups_out)
        .set("verified", true);
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::anyprec::Codes;
    use crate::util::npz::{write_npz, NpyData};
    use crate::util::rng::{for_each_seed, Rng};

    fn tmp(name: &str) -> String {
        std::env::temp_dir().join(name).to_string_lossy().into_owned()
    }

    /// Random full store over all 7 groups with realistic shape coupling
    /// (attention groups square, MLP groups rectangular), plus the raw
    /// layer-major npz members so the same weights can go down the
    /// legacy path.
    fn synth(rng: &mut Rng) -> (AnyPrecStore, Vec<(String, Vec<usize>, NpyData)>) {
        let l = rng.range(1, 3);
        let d = 8 * rng.range(1, 3);
        let f = 8 * rng.range(2, 4);
        let mut groups = BTreeMap::new();
        let mut members = Vec::new();
        for g in GROUPS {
            let (out, n_in) = match g {
                "wg" | "wu" => (f, d),
                "wd" => (d, f),
                _ => (d, d),
            };
            let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
            for b in planes.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            let mut luts = BTreeMap::new();
            for b in MIN_BITS..=MAX_BITS {
                let w = 1usize << b;
                let lut: Vec<f32> =
                    (0..l * out * w).map(|_| rng.f32() * 2.0 - 1.0).collect();
                members.push((format!("lut{b}_{g}"), vec![l, out, w],
                              NpyData::F32(lut.clone())));
                luts.insert(b, lut);
            }
            members.push((format!("planes_{g}"), vec![l, 6, out, n_in / 8],
                          NpyData::U8(planes.clone())));
            groups.insert(
                g.to_string(),
                GroupStore::from_layer_major(&planes, l, out, n_in, luts).unwrap(),
            );
        }
        let store = AnyPrecStore {
            groups, meta: None, map: None, stats: LoadStats::default(),
        };
        (store, members)
    }

    fn write_members_npz(path: &str, members: &[(String, Vec<usize>, NpyData)]) {
        let refs: Vec<(&str, &[usize], NpyData)> = members
            .iter()
            .map(|(n, s, d)| (n.as_str(), s.as_slice(), d.clone()))
            .collect();
        write_npz(path, &refs).unwrap();
    }

    fn dpak_err(err: &anyhow::Error) -> DpakError {
        err.downcast_ref::<DpakError>()
            .unwrap_or_else(|| panic!("expected DpakError, got: {err:#}"))
            .clone()
    }

    /// Acceptance: pack → load_dpak is bit-identical to the npz path
    /// over randomized stores, all groups and bitwidths — and the DPAK
    /// path copies zero plane bytes while the npz path copies them all.
    #[test]
    fn roundtrip_bit_identical_with_npz_path() {
        for_each_seed(5, |rng| {
            let (store, members) = synth(rng);
            let npz_path = tmp(&format!("dpllm_dpak_rt_{}.npz", rng.next_u64()));
            let dpak_path = npz_path.replace(".npz", ".dpak");
            write_members_npz(&npz_path, &members);
            write(&store, "synth", &dpak_path).unwrap();

            let via_npz = AnyPrecStore::load(&npz_path).unwrap();
            let via_dpak = AnyPrecStore::load_dpak(&dpak_path).unwrap();

            // zero plane-byte copies on the dpak path; all-copy on npz
            assert_eq!(via_dpak.stats().plane_bytes_copied, 0);
            assert!(via_dpak.stats().plane_bytes_mapped > 0);
            assert_eq!(via_npz.stats().plane_bytes_mapped, 0);
            assert_eq!(via_npz.stats().plane_bytes_copied,
                       via_dpak.stats().plane_bytes_mapped);

            for g in GROUPS {
                let a = via_npz.group(g).unwrap();
                let b = via_dpak.group(g).unwrap();
                assert_eq!((a.n_layers, a.out_dim, a.in_dim),
                           (b.n_layers, b.out_dim, b.in_dim));
                for layer in 0..a.n_layers {
                    for p in 0..MAX_BITS as usize {
                        assert_eq!(a.plane_layer(p, layer).unwrap(),
                                   b.plane_layer(p, layer).unwrap(),
                                   "{g} plane {p} layer {layer}");
                    }
                    for bits in MIN_BITS..=MAX_BITS {
                        assert_eq!(a.dequant(layer, bits).unwrap().data,
                                   b.dequant(layer, bits).unwrap().data,
                                   "{g} layer {layer} bits {bits}");
                    }
                }
            }
            std::fs::remove_file(&npz_path).ok();
            std::fs::remove_file(&dpak_path).ok();
        });
    }

    /// Acceptance: `load_slice(4)` maps strictly fewer bytes than a full
    /// load, and serves its resident bitwidths bit-identically while
    /// refusing the others.  The codes path honors residency too.
    #[test]
    fn tier_slice_maps_fewer_bytes() {
        let mut rng = Rng::new(0xD9A4);
        let (store, _) = synth(&mut rng);
        let path = tmp("dpllm_dpak_slice.dpak");
        write(&store, "synth", &path).unwrap();

        let full = AnyPrecStore::load_dpak(&path).unwrap();
        let s4 = AnyPrecStore::load_slice(&path, 4).unwrap();
        let s3 = AnyPrecStore::load_slice(&path, 3).unwrap();
        assert!(s4.stats().plane_bytes_mapped < full.stats().plane_bytes_mapped);
        assert!(s3.stats().plane_bytes_mapped < s4.stats().plane_bytes_mapped);
        assert!(s4.stats().lut_bytes_mapped + s4.stats().lut_bytes_copied
                < full.stats().lut_bytes_mapped + full.stats().lut_bytes_copied);
        assert_eq!(s4.max_bits(), 4);

        let g = "wq";
        assert_eq!(s4.group(g).unwrap().dequant(0, 4).unwrap().data,
                   full.group(g).unwrap().dequant(0, 4).unwrap().data);
        assert!(s4.group(g).unwrap().dequant(0, 5).is_err());
        let mut codes = Codes::new();
        s4.group(g).unwrap().dequant_codes_into(0, 4, &mut codes).unwrap();
        assert!(s4.group(g).unwrap().refine_codes_into(0, &mut codes).is_err());
        std::fs::remove_file(&path).ok();
    }

    /// Acceptance: N replica views share ONE mapping — observable via the
    /// `Arc<Mmap>` refcount.
    #[test]
    fn replicas_share_one_mapping() {
        let mut rng = Rng::new(0x5A5A);
        let (store, _) = synth(&mut rng);
        let path = tmp("dpllm_dpak_share.dpak");
        write(&store, "synth", &path).unwrap();

        let full = AnyPrecStore::load_dpak(&path).unwrap();
        let map = full.mapping().expect("dpak store carries its mapping").clone();
        assert_eq!(Arc::strong_count(&map), 2); // full.map + our clone
        let replicas: Vec<AnyPrecStore> =
            (0..4).map(|i| full.slice(3 + (i % 4) as u8).unwrap()).collect();
        assert_eq!(Arc::strong_count(&map), 6);
        // every replica's planes read through the same physical bytes
        for r in &replicas {
            assert!(std::ptr::eq(
                r.group("wq").unwrap().plane_layer(0, 0).unwrap().as_ptr(),
                full.group("wq").unwrap().plane_layer(0, 0).unwrap().as_ptr(),
            ));
        }
        drop(replicas);
        drop(full);
        assert_eq!(Arc::strong_count(&map), 1);
        std::fs::remove_file(&path).ok();
    }

    /// Corruption suite: every failure mode is a typed error, no panics.
    #[test]
    fn corrupted_containers_refused_with_typed_errors() {
        let mut rng = Rng::new(0xC0DE);
        let (store, _) = synth(&mut rng);
        let path = tmp("dpllm_dpak_corrupt.dpak");
        write(&store, "synth", &path).unwrap();
        let good = std::fs::read(&path).unwrap();

        // not a dpak at all
        std::fs::write(&path, b"PAKD nope").unwrap();
        assert_eq!(dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()),
                   DpakError::BadMagic);

        // future format version
        let mut v2 = good.clone();
        v2[4] = 9;
        std::fs::write(&path, &v2).unwrap();
        assert_eq!(dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()),
                   DpakError::UnsupportedFormatVersion(9));

        // header cut short
        std::fs::write(&path, &good[..10]).unwrap();
        assert!(matches!(dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()),
                         DpakError::Truncated { .. }));

        // file truncated mid-section
        std::fs::write(&path, &good[..good.len() - 64]).unwrap();
        assert!(matches!(dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()),
                         DpakError::Truncated { .. }));

        // single flipped bit in the LAST plane section byte — the digest
        // must catch it
        let mut flipped = good.clone();
        let last = flipped.len() - 1;
        flipped[last] ^= 0x10;
        std::fs::write(&path, &flipped).unwrap();
        // (last section is lut6 of the last group; any section works)
        assert!(matches!(dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()),
                         DpakError::DigestMismatch { .. }));

        // flip a bit inside the FIRST data section (a plane) specifically
        let mlen = u64::from_le_bytes(good[8..16].try_into().unwrap()) as usize;
        let data_start = (16 + mlen + DPAK_ALIGN - 1) / DPAK_ALIGN * DPAK_ALIGN;
        let mut flipped = good.clone();
        flipped[data_start] ^= 0x01;
        std::fs::write(&path, &flipped).unwrap();
        match dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()) {
            DpakError::DigestMismatch { section, .. } => {
                assert_eq!(section, "plane0/wq");
            }
            other => panic!("wrong error: {other}"),
        }

        // manifest/section offset mismatch: tamper the manifest's length
        // field for plane0/wq (same digit count keeps the JSON well-formed)
        let text = String::from_utf8(good[16..16 + mlen].to_vec()).unwrap();
        let m = Json::parse(&text).unwrap();
        let len = m.req("groups").unwrap().req("wq").unwrap()
            .req("planes").unwrap().as_arr().unwrap()[0]
            .usize_of("len").unwrap();
        let needle = format!("\"len\":{len}");
        // mutate the last digit in place: always same digit count, always
        // a different value, so the manifest stays byte-for-byte resizable
        let mut digits = len.to_string().into_bytes();
        let last = digits.last_mut().unwrap();
        *last = if *last == b'9' { b'0' } else { *last + 1 };
        let bad_len = format!("\"len\":{}", String::from_utf8(digits).unwrap());
        let tampered_text = text.replacen(&needle, &bad_len, 1);
        let mut tampered = good.clone();
        tampered[16..16 + mlen].copy_from_slice(tampered_text.as_bytes());
        std::fs::write(&path, &tampered).unwrap();
        assert!(matches!(dpak_err(&AnyPrecStore::load_dpak(&path).unwrap_err()),
                         DpakError::OffsetMismatch { .. }));

        std::fs::remove_file(&path).ok();
    }

    /// The serve-time gate: wrong model or stale version is a typed
    /// refusal; matching identity passes.
    #[test]
    fn version_gate_refuses_mismatches() {
        let mut rng = Rng::new(0x6A7E);
        let (store, _) = synth(&mut rng);
        let path = tmp("dpllm_dpak_gate.dpak");
        let meta = write(&store, "dpl-tiny", &path).unwrap();
        let loaded = AnyPrecStore::load_dpak(&path).unwrap();
        assert_eq!(loaded.meta().unwrap().model, "dpl-tiny");
        assert_eq!(loaded.meta().unwrap().version, meta.version);

        check_version_gate(loaded.meta().unwrap(), "dpl-tiny", None).unwrap();
        check_version_gate(loaded.meta().unwrap(), "dpl-tiny",
                           Some(&meta.version)).unwrap();
        match dpak_err(&check_version_gate(loaded.meta().unwrap(), "other-model",
                                           None).unwrap_err()) {
            DpakError::VersionGate { field, .. } => assert_eq!(field, "model"),
            other => panic!("wrong error: {other}"),
        }
        match dpak_err(&check_version_gate(loaded.meta().unwrap(), "dpl-tiny",
                                           Some("crc32:00000000")).unwrap_err()) {
            DpakError::VersionGate { field, .. } => assert_eq!(field, "version"),
            other => panic!("wrong error: {other}"),
        }
        std::fs::remove_file(&path).ok();
    }

    /// `dpllm inspect` smoke: summary fields present, deep verification
    /// passes on a good container and names the bad layer on a corrupt one.
    #[test]
    fn inspect_smoke() {
        let mut rng = Rng::new(0x1A5B);
        let (store, _) = synth(&mut rng);
        let path = tmp("dpllm_dpak_inspect.dpak");
        let meta = write(&store, "dpl-tiny", &path).unwrap();

        let j = inspect(&path).unwrap();
        assert_eq!(j.str_of("model").unwrap(), "dpl-tiny");
        assert_eq!(j.str_of("version").unwrap(), meta.version);
        assert_eq!(j.usize_of("max_bits").unwrap(), 6);
        assert_eq!(j.usize_of("sections").unwrap(), 7 * 6 + 7 * 4);
        assert!(j.req("verified").unwrap().as_bool().unwrap());
        let wq = j.req("groups").unwrap().req("wq").unwrap();
        assert!(wq.usize_of("plane_bytes").unwrap() > 0);

        // corrupt one byte of plane data → inspect names the layer
        let mut bytes = std::fs::read(&path).unwrap();
        let mlen = u64::from_le_bytes(bytes[8..16].try_into().unwrap()) as usize;
        let data_start = (16 + mlen + DPAK_ALIGN - 1) / DPAK_ALIGN * DPAK_ALIGN;
        bytes[data_start] ^= 0x80;
        std::fs::write(&path, &bytes).unwrap();
        match dpak_err(&inspect(&path).unwrap_err()) {
            DpakError::DigestMismatch { section, .. } => {
                assert!(section.starts_with("plane0/wq"), "{section}");
            }
            other => panic!("wrong error: {other}"),
        }
        std::fs::remove_file(&path).ok();
    }
}
