//! Any-precision weight store: bitplane-packed nested codes + per-bitwidth
//! centroid tables (the Rust twin of the format defined in
//! `python/compile/kernels/ref.py` and produced by `quantize.py`).
//!
//! The store holds ONE copy of the 6-bit codes; every bitwidth 3..6 is a
//! view over the top-b planes — this is the memory-overlay property of
//! Any-Precision LLM that makes runtime adaptation feasible on-device.
//! The coordinator uses this module to *materialize* per-configuration
//! `W_l` / `W_h` stacks at model-load time and on precision rebinds
//! (config switch, not request path), and to account memory for Table 9.
//!
//! **Ownership (DESIGN.md §Artifact):** plane and LUT buffers are
//! [`PlaneBytes`] / [`LutBytes`] — either owned heap copies (the legacy
//! `.npz` path) or borrowed ranges of one reference-counted read-only
//! mmap of a DPAK container ([`dpak`]), in which case loading copies
//! **zero** plane bytes and N replicas share a single physical mapping.
//! Planes are held plane-major (`planes[p]` = all layers of bitplane
//! `p`), so a *tier slice* — [`AnyPrecStore::load_slice`] with
//! `max_bits < 6` — simply maps fewer sections: an economy replica never
//! touches the 5–6-bit planes.  [`LoadStats`] meters what each load
//! mapped vs copied.
//!
//! Materialization is the config-switch hot path (DESIGN.md §Perf), so the
//! dequantizer comes in three speeds:
//!
//! * [`GroupStore::dequant_into`] — the **word-level kernel**: each packed
//!   byte of each plane is spread across the 8 byte-lanes of a `u64` via a
//!   precomputed 256-entry table ([`SPREAD`]), so 8 codes materialize with
//!   `bits` table lookups + shifts instead of `8 × bits` single-bit
//!   extractions, with `std::thread::scope` row-parallelism for large
//!   slabs and no per-layer allocation;
//! * [`GroupStore::refine_codes_into`] — the **incremental path**: the
//!   nested-prefix property (`code_{b+1} = code_b << 1 | bit_b`) turns a
//!   b→b+1 re-materialization into a single-plane walk.  Codes travel in
//!   the [`Codes`] newtype, which carries their current bitwidth so
//!   [`GroupStore::lut_map_into`] can *refuse* a mismatched mapping
//!   instead of silently yielding wrong weights;
//! * [`GroupStore::dequant_reference`] — the original naive per-bit loop,
//!   retained as the differential-test oracle and bench baseline.

pub mod dpak;
pub mod materialize;

use std::collections::BTreeMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::mmap::Mmap;
use crate::util::npz::{load_npz, NpyArray};

pub use dpak::{DpakError, DpakMeta};

pub const GROUPS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];
pub const MIN_BITS: u8 = 3;
pub const MAX_BITS: u8 = 6;

/// Slabs below this element count dequantize on the calling thread; the
/// scoped-thread fan-out only pays off once the rows amortize spawn cost.
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Byte → bit-spread table: byte lane `j` of `SPREAD[v]` holds bit `j` of
/// `v`.  ORing shifted spreads of the top `b` plane bytes assembles the 8
/// codes of one packed byte in `b` lookups; lanes never carry into each
/// other because codes stay < 2^6 < 2^7.
static SPREAD: [u64; 256] = build_spread();

const fn build_spread() -> [u64; 256] {
    let mut table = [0u64; 256];
    let mut v = 0usize;
    while v < 256 {
        let mut j = 0;
        let mut acc = 0u64;
        while j < 8 {
            acc |= (((v >> j) & 1) as u64) << (8 * j);
            j += 1;
        }
        table[v] = acc;
        v += 1;
    }
    table
}

/// Assemble the 8 codes of packed-byte column `byte` from MSB-first plane
/// rows: lane `j` of the result is the code of element `byte*8 + j`.  The
/// single word-assembly step shared by the dequant and codes paths — keep
/// the packing convention in exactly one place.
#[inline(always)]
fn gather_codes(prows: &[&[u8]], byte: usize) -> u64 {
    let mut codes = 0u64;
    for prow in prows {
        codes = (codes << 1) | SPREAD[prow[byte] as usize];
    }
    codes
}

/// One bitplane's backing storage: an owned copy (legacy npz path, or
/// hand-built test stores) or a borrowed range of a shared read-only
/// mapping (DPAK path — zero plane-byte copies, one mapping per node).
#[derive(Clone)]
pub enum PlaneBytes {
    Owned(Arc<[u8]>),
    Mapped { map: Arc<Mmap>, off: usize, len: usize },
}

impl PlaneBytes {
    pub fn as_slice(&self) -> &[u8] {
        match self {
            PlaneBytes::Owned(v) => v,
            PlaneBytes::Mapped { map, off, len } => &map[*off..*off + *len],
        }
    }

    pub fn len(&self) -> usize {
        match self {
            PlaneBytes::Owned(v) => v.len(),
            PlaneBytes::Mapped { len, .. } => *len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn is_mapped(&self) -> bool {
        matches!(self, PlaneBytes::Mapped { .. })
    }
}

/// One bitwidth's centroid table: owned f32s or an aligned borrowed range
/// of the shared mapping (DPAK sections are 64-byte aligned, so the
/// reinterpret below is always in-bounds and aligned; the loader checks).
#[derive(Clone)]
pub enum LutBytes {
    Owned(Arc<[f32]>),
    /// `off` is a byte offset into `map`, 4-aligned; `n` counts f32s.
    Mapped { map: Arc<Mmap>, off: usize, n: usize },
}

impl LutBytes {
    pub fn as_f32(&self) -> &[f32] {
        match self {
            LutBytes::Owned(v) => v,
            LutBytes::Mapped { map, off, n } => {
                let bytes = &map[*off..*off + *n * 4];
                debug_assert_eq!(bytes.as_ptr() as usize % 4, 0);
                // SAFETY: the DPAK loader only constructs this variant
                // after checking 4-byte alignment and little-endian host;
                // the range is in-bounds of the mapping for its lifetime.
                unsafe {
                    std::slice::from_raw_parts(bytes.as_ptr() as *const f32, *n)
                }
            }
        }
    }

    pub fn len(&self) -> usize {
        match self {
            LutBytes::Owned(v) => v.len(),
            LutBytes::Mapped { n, .. } => *n,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn is_mapped(&self) -> bool {
        matches!(self, LutBytes::Mapped { .. })
    }
}

/// A codes buffer whose current bitwidth travels with the data.
///
/// The codes-level API used to take bare `&[u8]`: codes refined to *b*
/// bits but mapped through the *b'*-bit LUT index in-bounds whenever
/// `b < b'` — silently yielding wrong weights.  The newtype closes that
/// hole: [`GroupStore::dequant_codes_into`] stamps the bitwidth,
/// [`GroupStore::refine_codes_into`] advances it, and
/// [`GroupStore::lut_map_into`] refuses any mismatch.
#[derive(Debug, Clone, Default)]
pub struct Codes {
    data: Vec<u8>,
    bits: u8,
}

impl Codes {
    pub fn new() -> Codes {
        Codes::default()
    }

    /// The bitwidth the buffer currently holds (0 = uninitialized).
    pub fn bits(&self) -> u8 {
        self.bits
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }

    /// Overwrite this buffer with another's contents *and* bitwidth
    /// (no reallocation once capacities match) — lets refine sweeps and
    /// benches reset to a checkpointed state without rebuilding codes.
    pub fn copy_from(&mut self, other: &Codes) {
        self.data.resize(other.data.len(), 0);
        self.data.copy_from_slice(&other.data);
        self.bits = other.bits;
    }
}

/// What a store load mapped vs copied — the zero-copy contract, metered.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LoadStats {
    /// Plane bytes copied into owned heap buffers (legacy npz path).
    pub plane_bytes_copied: u64,
    /// Plane bytes served as borrowed ranges of the container mapping.
    pub plane_bytes_mapped: u64,
    pub lut_bytes_copied: u64,
    pub lut_bytes_mapped: u64,
    /// Wall time of the load (parse + digest verification included).
    pub load_ms: f64,
    /// Backed by a live kernel mapping (false: owned-read fallback).
    pub mapped: bool,
}

/// Packed planes + LUTs for one linear group (stacked over layers).
///
/// Planes are **plane-major**: `planes[p]` holds bitplane `p` (0 = MSB)
/// for every layer, laid out `[L, out, in/8]`.  Only planes
/// `0..max_bits` are resident — a tier-sliced store simply holds fewer
/// entries, and any dequant above `max_bits` fails loudly.
#[derive(Clone)]
pub struct GroupStore {
    planes: Vec<PlaneBytes>,
    pub n_layers: usize,
    pub out_dim: usize,
    pub in_dim: usize,
    /// LUT per resident bitwidth b (3..=max_bits): f32 `[L, out, 2^b]`.
    luts: BTreeMap<u8, LutBytes>,
    max_bits: u8,
}

impl GroupStore {
    /// Build an owned store from the legacy layer-major layout
    /// `[L, 6, out, in/8]` (what `quantize.py` packs into npz) — copies
    /// the planes once to transpose them plane-major.
    pub fn from_layer_major(planes_lm: &[u8], n_layers: usize, out_dim: usize,
                            in_dim: usize, luts: BTreeMap<u8, Vec<f32>>)
                            -> Result<GroupStore> {
        if in_dim % 8 != 0 {
            bail!("in_dim {in_dim} not a multiple of 8 (bitplane packing)");
        }
        let bytes_in = in_dim / 8;
        let layer_bytes = out_dim * bytes_in;
        let want = n_layers * 6 * layer_bytes;
        if planes_lm.len() != want {
            bail!(
                "plane buffer holds {} bytes, shape [L={n_layers}, 6, out={out_dim}, \
                 in/8={bytes_in}] wants {want}",
                planes_lm.len()
            );
        }
        let nb = MAX_BITS as usize;
        let mut planes = Vec::with_capacity(nb);
        for p in 0..nb {
            let mut buf = Vec::with_capacity(n_layers * layer_bytes);
            for l in 0..n_layers {
                let src = (l * 6 + p) * layer_bytes;
                buf.extend_from_slice(&planes_lm[src..src + layer_bytes]);
            }
            planes.push(PlaneBytes::Owned(Arc::from(buf)));
        }
        let luts = luts
            .into_iter()
            .map(|(b, v)| (b, LutBytes::Owned(Arc::from(v))))
            .collect();
        let store = GroupStore {
            planes, n_layers, out_dim, in_dim, luts, max_bits: MAX_BITS,
        };
        store.validate()?;
        Ok(store)
    }

    /// Resident precision ceiling: dequants above this bitwidth error.
    pub fn max_bits(&self) -> u8 {
        self.max_bits
    }

    /// A cheap sliced view holding only planes/LUTs ≤ `max_bits` (Arc
    /// clones — no plane bytes move).  The per-replica residency cut.
    pub fn slice(&self, max_bits: u8) -> Result<GroupStore> {
        if !(MIN_BITS..=MAX_BITS).contains(&max_bits) {
            bail!("slice max_bits {max_bits} out of range {MIN_BITS}..={MAX_BITS}");
        }
        if max_bits > self.max_bits {
            bail!(
                "slice max_bits {max_bits} exceeds resident precision {} — \
                 cannot widen a tier-sliced store",
                self.max_bits
            );
        }
        let store = GroupStore {
            planes: self.planes[..max_bits as usize].to_vec(),
            n_layers: self.n_layers,
            out_dim: self.out_dim,
            in_dim: self.in_dim,
            luts: self
                .luts
                .iter()
                .filter(|(b, _)| **b <= max_bits)
                .map(|(b, l)| (*b, l.clone()))
                .collect(),
            max_bits,
        };
        store.validate()?;
        Ok(store)
    }

    /// Bitplane `p` of one layer: `[out, in/8]` bytes.
    pub fn plane_layer(&self, p: usize, layer: usize) -> Result<&[u8]> {
        if p >= self.planes.len() {
            bail!("plane {p} not resident (store holds {} planes)", self.planes.len());
        }
        if layer >= self.n_layers {
            bail!("layer {layer} out of range ({})", self.n_layers);
        }
        let layer_bytes = self.out_dim * self.in_dim / 8;
        Ok(&self.planes[p].as_slice()[layer * layer_bytes..(layer + 1) * layer_bytes])
    }

    /// The LUT for `bits`: f32 `[L, out, 2^bits]` flattened.
    pub fn lut(&self, bits: u8) -> Result<&[f32]> {
        self.luts
            .get(&bits)
            .map(|l| l.as_f32())
            .ok_or_else(|| anyhow!("missing lut for {bits} bits"))
    }

    /// Resident plane bytes (what this view keeps reachable).
    pub fn resident_plane_bytes(&self) -> usize {
        self.planes.iter().map(|p| p.len()).sum()
    }

    fn resident_lut_bytes(&self) -> usize {
        self.luts.values().map(|l| l.len() * 4).sum()
    }

    /// Structural invariants every dequant path assumes.  Run once at
    /// load so a malformed artifact fails loudly at load time instead of
    /// truncating or panicking mid-request.
    pub fn validate(&self) -> Result<()> {
        if self.n_layers == 0 || self.out_dim == 0 || self.in_dim == 0 {
            bail!(
                "degenerate store shape [L={}, out={}, in={}]",
                self.n_layers, self.out_dim, self.in_dim
            );
        }
        if self.in_dim % 8 != 0 {
            bail!("in_dim {} not a multiple of 8 (bitplane packing)", self.in_dim);
        }
        if !(MIN_BITS..=MAX_BITS).contains(&self.max_bits) {
            bail!("max_bits {} out of range {MIN_BITS}..={MAX_BITS}", self.max_bits);
        }
        if self.planes.len() != self.max_bits as usize {
            bail!(
                "store holds {} planes, max_bits {} wants that many",
                self.planes.len(), self.max_bits
            );
        }
        let want_plane = self.n_layers * self.out_dim * self.in_dim / 8;
        for (p, plane) in self.planes.iter().enumerate() {
            if plane.len() != want_plane {
                bail!(
                    "plane {p} holds {} bytes, shape [L={}, out={}, in/8={}] wants {}",
                    plane.len(), self.n_layers, self.out_dim, self.in_dim / 8,
                    want_plane
                );
            }
        }
        for b in MIN_BITS..=self.max_bits {
            let lut = self
                .luts
                .get(&b)
                .ok_or_else(|| anyhow!("missing lut for {b} bits"))?;
            let want = self.n_layers * self.out_dim * (1 << b);
            if lut.len() != want {
                bail!("lut{} holds {} entries, wants {}", b, lut.len(), want);
            }
        }
        Ok(())
    }

    fn check_layer_bits(&self, layer: usize, bits: u8) -> Result<&[f32]> {
        if !(MIN_BITS..=MAX_BITS).contains(&bits) {
            bail!("bits {bits} out of range");
        }
        if bits > self.max_bits {
            bail!(
                "bits {bits} exceed resident precision {} — tier-sliced store; \
                 load a wider slice to serve this bitwidth",
                self.max_bits
            );
        }
        if layer >= self.n_layers {
            bail!("layer {layer} out of range ({})", self.n_layers);
        }
        self.lut(bits)
    }

    /// Word-level kernel core over rows `[row0, row0 + dst.len()/in_dim)`
    /// of one layer.  Preconditions (layer/bits/lut/length) are validated
    /// by the public entry points.  Dispatches to a bit-count-monomorphized
    /// body so the per-plane loop fully unrolls and the `lut_w - 1` mask
    /// provably bounds the LUT index (no per-element bounds check).
    fn dequant_rows(&self, layer: usize, bits: u8, lut: &[f32], row0: usize,
                    dst: &mut [f32]) {
        match bits {
            3 => self.dequant_rows_n::<3>(layer, lut, row0, dst),
            4 => self.dequant_rows_n::<4>(layer, lut, row0, dst),
            5 => self.dequant_rows_n::<5>(layer, lut, row0, dst),
            _ => self.dequant_rows_n::<6>(layer, lut, row0, dst),
        }
    }

    fn dequant_rows_n<const NB: usize>(&self, layer: usize, lut: &[f32],
                                       row0: usize, dst: &mut [f32]) {
        if self.in_dim == 0 {
            return; // degenerate hand-built store; load-time validate rejects
        }
        let bytes_in = self.in_dim / 8;
        let layer_bytes = self.out_dim * bytes_in;
        let lut_w = 1usize << NB;
        let lut_base = layer * self.out_dim * lut_w;
        let mask = lut_w - 1;
        let nrows = dst.len() / self.in_dim;
        let pbufs: [&[u8]; NB] = std::array::from_fn(|p| self.planes[p].as_slice());
        for r in 0..nrows {
            let o = row0 + r;
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let row_dst = &mut dst[r * self.in_dim..(r + 1) * self.in_dim];
            let base = layer * layer_bytes + o * bytes_in;
            let prows: [&[u8]; NB] =
                std::array::from_fn(|p| &pbufs[p][base..base + bytes_in]);
            for byte in 0..bytes_in {
                let codes = gather_codes(&prows, byte);
                let cell = &mut row_dst[byte * 8..byte * 8 + 8];
                for (j, c) in cell.iter_mut().enumerate() {
                    *c = row_lut[(codes >> (8 * j)) as usize & mask];
                }
            }
        }
    }

    /// Shared precondition check of the `dequant_into*` entry points:
    /// layer/bits in range, LUT present, destination exactly one slab.
    fn checked_lut(&self, layer: usize, bits: u8, out_len: usize) -> Result<&[f32]> {
        let lut = self.check_layer_bits(layer, bits)?;
        if out_len != self.out_dim * self.in_dim {
            bail!(
                "dequant_into buffer holds {} elements, layer wants {}",
                out_len, self.out_dim * self.in_dim
            );
        }
        Ok(lut)
    }

    /// Dequantize one layer at `bits` into caller-owned storage (the
    /// allocation-free variant) — word-level, single-threaded.
    pub fn dequant_into_serial(&self, layer: usize, bits: u8,
                               out: &mut [f32]) -> Result<()> {
        let lut = self.checked_lut(layer, bits, out.len())?;
        self.dequant_rows(layer, bits, lut, 0, out);
        Ok(())
    }

    /// [`GroupStore::dequant_into_serial`] with scoped-thread parallelism
    /// over `out_dim` rows for large slabs (no extra dependencies; small
    /// slabs stay on the calling thread).
    pub fn dequant_into(&self, layer: usize, bits: u8, out: &mut [f32]) -> Result<()> {
        let lut = self.checked_lut(layer, bits, out.len())?;
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(self.out_dim);
        if threads <= 1 || out.len() < PAR_MIN_ELEMS {
            self.dequant_rows(layer, bits, lut, 0, out);
            return Ok(());
        }
        let rows_per = (self.out_dim + threads - 1) / threads;
        std::thread::scope(|s| {
            for (ci, chunk) in out.chunks_mut(rows_per * self.in_dim).enumerate() {
                s.spawn(move || self.dequant_rows(layer, bits, lut, ci * rows_per, chunk));
            }
        });
        Ok(())
    }

    /// Dequantize one layer at `bits` into a fresh `[out, in]` tensor.
    pub fn dequant(&self, layer: usize, bits: u8) -> Result<Tensor> {
        let mut out = vec![0f32; self.out_dim * self.in_dim];
        self.dequant_into(layer, bits, &mut out)?;
        Tensor::new(vec![self.out_dim, self.in_dim], out)
    }

    /// The original per-bit dequantizer, retained as the reference oracle
    /// for the differential property tests and the bench baseline.  Same
    /// semantics as [`GroupStore::dequant`], ~an order of magnitude slower.
    pub fn dequant_reference(&self, layer: usize, bits: u8) -> Result<Tensor> {
        let lut = self.check_layer_bits(layer, bits)?;
        let bytes_in = self.in_dim / 8;
        let layer_bytes = self.out_dim * bytes_in;
        let lut_w = 1usize << bits;
        let lut_base = layer * self.out_dim * lut_w;
        let mut out = vec![0f32; self.out_dim * self.in_dim];
        for o in 0..self.out_dim {
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let dst = &mut out[o * self.in_dim..(o + 1) * self.in_dim];
            let base = layer * layer_bytes + o * bytes_in;
            for byte in 0..bytes_in {
                // gather the byte of each of the top `bits` planes
                let mut plane_bytes = [0u8; 6];
                for (p, pb) in plane_bytes.iter_mut().enumerate().take(bits as usize) {
                    *pb = self.planes[p].as_slice()[base + byte];
                }
                for j in 0..8 {
                    let mut code = 0usize;
                    for pb in plane_bytes.iter().take(bits as usize) {
                        code = (code << 1) | ((pb >> j) & 1) as usize;
                    }
                    dst[byte * 8 + j] = row_lut[code];
                }
            }
        }
        Tensor::new(vec![self.out_dim, self.in_dim], out)
    }

    /// Materialize one layer's **codes** (not centroid values) at `bits`,
    /// word-level, stamping the buffer's bitwidth.  The codes buffer is
    /// the refinement state for [`GroupStore::refine_codes_into`]; it is
    /// (re)sized here, so one buffer can be reused across layers/groups.
    pub fn dequant_codes_into(&self, layer: usize, bits: u8,
                              codes: &mut Codes) -> Result<()> {
        self.check_layer_bits(layer, bits)?;
        codes.data.resize(self.out_dim * self.in_dim, 0);
        codes.bits = bits;
        let bytes_in = self.in_dim / 8;
        let layer_bytes = self.out_dim * bytes_in;
        let nb = bits as usize;
        let empty: &[u8] = &[];
        let mut pbufs: [&[u8]; 6] = [empty; 6];
        for (p, slot) in pbufs.iter_mut().enumerate().take(nb) {
            *slot = self.planes[p].as_slice();
        }
        for o in 0..self.out_dim {
            let row = &mut codes.data[o * self.in_dim..(o + 1) * self.in_dim];
            let base = layer * layer_bytes + o * bytes_in;
            let mut prows: [&[u8]; 6] = [empty; 6];
            for (p, slot) in prows.iter_mut().enumerate().take(nb) {
                *slot = &pbufs[p][base..base + bytes_in];
            }
            for byte in 0..bytes_in {
                let w = gather_codes(&prows[..nb], byte);
                let cell = &mut row[byte * 8..byte * 8 + 8];
                for (j, c) in cell.iter_mut().enumerate() {
                    *c = ((w >> (8 * j)) & 0x3f) as u8;
                }
            }
        }
        Ok(())
    }

    /// Incremental refinement by one bit: append the next plane's bit to
    /// every code (`code_{b+1} = code_b << 1 | bit_b`).  Reads exactly ONE
    /// plane instead of re-walking all `b+1`, which is what makes sweeping
    /// 3→4→5→6 (calibration, candidate probing) cost one full dequant plus
    /// three single-plane passes.  The source bitwidth comes from the
    /// [`Codes`] buffer itself — there is no `from_bits` to get wrong.
    pub fn refine_codes_into(&self, layer: usize, codes: &mut Codes) -> Result<()> {
        let from_bits = codes.bits;
        if !(MIN_BITS..MAX_BITS).contains(&from_bits) {
            bail!("refine from {from_bits} bits: need {MIN_BITS}..{}", MAX_BITS - 1);
        }
        if from_bits >= self.max_bits {
            bail!(
                "refine to {} bits: plane not resident (tier-sliced store \
                 holds {} bits)",
                from_bits + 1, self.max_bits
            );
        }
        if layer >= self.n_layers {
            bail!("layer {layer} out of range ({})", self.n_layers);
        }
        if codes.data.len() != self.out_dim * self.in_dim {
            bail!(
                "codes buffer holds {} elements, layer wants {}",
                codes.data.len(), self.out_dim * self.in_dim
            );
        }
        let bytes_in = self.in_dim / 8;
        let layer_bytes = self.out_dim * bytes_in;
        // planes 0..from_bits gave the prefix; plane[from_bits] appends
        let plane = self.planes[from_bits as usize].as_slice();
        for o in 0..self.out_dim {
            let row = &mut codes.data[o * self.in_dim..(o + 1) * self.in_dim];
            let base = layer * layer_bytes + o * bytes_in;
            for byte in 0..bytes_in {
                let pb = plane[base + byte];
                let cell = &mut row[byte * 8..byte * 8 + 8];
                for (j, c) in cell.iter_mut().enumerate() {
                    *c = (*c << 1) | ((pb >> j) & 1);
                }
            }
        }
        codes.bits = from_bits + 1;
        Ok(())
    }

    /// Map a codes buffer through the layer's `bits`-bit LUT.  The codes'
    /// own bitwidth must equal `bits` — a mismatch is a hard error, never
    /// a silent wrong-weight mapping (codes at lower bitwidths index the
    /// LUT in-bounds, which is exactly why the old bare-slice API could
    /// not catch this).
    pub fn lut_map_into(&self, layer: usize, bits: u8, codes: &Codes,
                        out: &mut [f32]) -> Result<()> {
        let lut = self.check_layer_bits(layer, bits)?;
        if codes.bits != bits {
            bail!(
                "codes refined to {} bits but lut_map requested {bits} — \
                 refusing mismatched codes (silent corruption hazard)",
                codes.bits
            );
        }
        let n = self.out_dim * self.in_dim;
        if codes.data.len() != n || out.len() != n {
            bail!("lut_map buffers hold {}/{} elements, layer wants {n}",
                  codes.data.len(), out.len());
        }
        let lut_w = 1usize << bits;
        let lut_base = layer * self.out_dim * lut_w;
        for o in 0..self.out_dim {
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let src = &codes.data[o * self.in_dim..(o + 1) * self.in_dim];
            let dst = &mut out[o * self.in_dim..(o + 1) * self.in_dim];
            for (d, &c) in dst.iter_mut().zip(src) {
                *d = row_lut[c as usize];
            }
        }
        Ok(())
    }

    /// Materialize the full `[L, out, in]` stack at per-layer bitwidths
    /// into one allocation (word-level per layer).
    pub fn dequant_stack(&self, bits_per_layer: &[u8]) -> Result<Tensor> {
        if bits_per_layer.len() != self.n_layers {
            bail!("need {} bit entries, got {}", self.n_layers, bits_per_layer.len());
        }
        let n = self.out_dim * self.in_dim;
        let mut data = vec![0f32; self.n_layers * n];
        for ((layer, &b), chunk) in
            bits_per_layer.iter().enumerate().zip(data.chunks_mut(n))
        {
            self.dequant_into(layer, b, chunk)?;
        }
        Tensor::new(vec![self.n_layers, self.out_dim, self.in_dim], data)
    }

    /// Bytes of packed storage actually touched at bitwidth `bits`
    /// (planes + LUT) — the memory-traffic model behind Tables 5/9.
    pub fn bytes_at(&self, bits: u8) -> usize {
        let planes = self.n_layers * bits as usize * self.out_dim * self.in_dim / 8;
        let lut = self.n_layers * self.out_dim * (1 << bits) * 4;
        planes + lut
    }

    /// Host bytes of one materialized layer slab (`[out, in]` f32).
    pub fn layer_slab_bytes(&self) -> usize {
        self.out_dim * self.in_dim * 4
    }
}

/// The full any-precision model store (7 groups).
pub struct AnyPrecStore {
    pub groups: BTreeMap<String, GroupStore>,
    /// DPAK manifest identity (None on the legacy npz path).
    meta: Option<DpakMeta>,
    /// The shared container mapping (None on the npz path).  Its
    /// `Arc::strong_count` is the number of live store views — the
    /// replicas-share-one-mapping invariant, observable in tests.
    map: Option<Arc<Mmap>>,
    stats: LoadStats,
}

impl AnyPrecStore {
    /// Legacy path: parse an uncompressed `.npz` and copy every plane/LUT
    /// into owned buffers (metered in [`LoadStats`] as copied bytes).
    pub fn load(path: &str) -> Result<AnyPrecStore> {
        let t0 = std::time::Instant::now();
        let arrays = load_npz(path)?;
        let mut groups = BTreeMap::new();
        let mut stats = LoadStats::default();
        for g in GROUPS {
            let planes = arrays
                .get(&format!("planes_{g}"))
                .ok_or_else(|| anyhow!("missing planes_{g} in {path}"))?;
            let shape = &planes.shape; // [L, 6, out, in/8]
            if shape.len() != 4 || shape[1] != 6 {
                bail!("planes_{g}: unexpected shape {:?}", shape);
            }
            let (n_layers, out_dim, in_dim) = (shape[0], shape[2], shape[3] * 8);
            let mut luts = BTreeMap::new();
            for b in MIN_BITS..=MAX_BITS {
                let lut: &NpyArray = arrays
                    .get(&format!("lut{b}_{g}"))
                    .ok_or_else(|| anyhow!("missing lut{b}_{g}"))?;
                if lut.shape != vec![n_layers, out_dim, 1 << b] {
                    bail!("lut{b}_{g}: unexpected shape {:?}", lut.shape);
                }
                let v = lut.to_f32();
                stats.lut_bytes_copied += (v.len() * 4) as u64;
                luts.insert(b, v);
            }
            let lm = planes.as_u8().context(format!("planes_{g}"))?;
            stats.plane_bytes_copied += lm.len() as u64;
            let store = GroupStore::from_layer_major(lm, n_layers, out_dim, in_dim, luts)
                .with_context(|| format!("planes_{g} in {path}"))?;
            groups.insert(g.to_string(), store);
        }
        stats.load_ms = t0.elapsed().as_secs_f64() * 1e3;
        Ok(AnyPrecStore { groups, meta: None, map: None, stats })
    }

    /// Zero-copy path: validate and map a DPAK container at full
    /// precision.  See [`dpak`] for the format.
    pub fn load_dpak(path: &str) -> Result<AnyPrecStore> {
        dpak::load(path, MAX_BITS)
    }

    /// Load only the planes/LUTs a precision tier needs: `.dpak` paths
    /// map just those sections; `.npz` paths parse fully (the zip gives
    /// no random access) and then drop the higher planes.
    pub fn load_slice(path: &str, max_bits: u8) -> Result<AnyPrecStore> {
        if path.ends_with(".dpak") {
            dpak::load(path, max_bits)
        } else {
            AnyPrecStore::load(path)?.slice(max_bits)
        }
    }

    /// A cheap sliced view of an already-loaded store (Arc clones; the
    /// container mapping, if any, is shared — this is how N replicas get
    /// per-tier residency out of one physical mapping).
    pub fn slice(&self, max_bits: u8) -> Result<AnyPrecStore> {
        let mut groups = BTreeMap::new();
        for (name, g) in &self.groups {
            groups.insert(
                name.clone(),
                g.slice(max_bits).with_context(|| format!("slicing group {name}"))?,
            );
        }
        let mut stats = tally(&groups);
        stats.mapped = self.stats.mapped;
        Ok(AnyPrecStore {
            groups,
            meta: self.meta.clone(),
            map: self.map.clone(),
            stats,
        })
    }

    pub fn group(&self, g: &str) -> Result<&GroupStore> {
        self.groups.get(g).ok_or_else(|| anyhow!("unknown group {g}"))
    }

    /// Total packed capacity at the given budget bitwidth (Table 9 rows).
    pub fn capacity_bytes(&self, bits: u8) -> usize {
        self.groups.values().map(|g| g.bytes_at(bits)).sum()
    }

    pub fn n_layers(&self) -> usize {
        self.groups.values().next().map(|g| g.n_layers).unwrap_or(0)
    }

    /// Resident precision ceiling across groups (= the slice bitwidth).
    pub fn max_bits(&self) -> u8 {
        self.groups.values().map(|g| g.max_bits).min().unwrap_or(MAX_BITS)
    }

    /// DPAK identity (model/version) — None for npz-loaded stores.
    pub fn meta(&self) -> Option<&DpakMeta> {
        self.meta.as_ref()
    }

    /// The shared container mapping, for refcount observation.
    pub fn mapping(&self) -> Option<&Arc<Mmap>> {
        self.map.as_ref()
    }

    pub fn stats(&self) -> LoadStats {
        self.stats
    }
}

/// Recompute mapped/copied byte tallies from what a set of groups holds.
fn tally(groups: &BTreeMap<String, GroupStore>) -> LoadStats {
    let mut s = LoadStats::default();
    for g in groups.values() {
        for p in &g.planes {
            if p.is_mapped() {
                s.plane_bytes_mapped += p.len() as u64;
            } else {
                s.plane_bytes_copied += p.len() as u64;
            }
        }
        for l in g.luts.values() {
            if l.is_mapped() {
                s.lut_bytes_mapped += (l.len() * 4) as u64;
            } else {
                s.lut_bytes_copied += (l.len() * 4) as u64;
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{for_each_seed, Rng};

    /// Build a tiny store by hand and check dequant against the format spec.
    fn toy_store() -> GroupStore {
        // 1 layer, 2 out rows, 16 in cols; col j in row o has 6-bit code
        // (j*4 + o) % 64.
        let (l, out, n_in) = (1usize, 2usize, 16usize);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        let code = |o: usize, j: usize| -> u8 { ((j * 4 + o) % 64) as u8 };
        for o in 0..out {
            for j in 0..n_in {
                let c = code(o, j);
                for p in 0..6 {
                    let bit = (c >> (5 - p)) & 1;
                    if bit == 1 {
                        let idx = p * out * (n_in / 8) + o * (n_in / 8) + j / 8;
                        planes[idx] |= 1 << (j % 8);
                    }
                }
            }
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            // lut[o][c] = c as f32 + o*100
            let mut lut = vec![0f32; l * out * w];
            for o in 0..out {
                for c in 0..w {
                    lut[o * w + c] = c as f32 + o as f32 * 100.0;
                }
            }
            luts.insert(b, lut);
        }
        GroupStore::from_layer_major(&planes, l, out, n_in, luts).unwrap()
    }

    /// Random store with arbitrary codes and LUT values (dims vary).
    fn random_store(rng: &mut Rng) -> GroupStore {
        let l = rng.range(1, 4);
        let out = rng.range(1, 6);
        let n_in = 8 * rng.range(1, 5);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        for b in planes.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            let lut: Vec<f32> =
                (0..l * out * w).map(|_| rng.f32() * 2.0 - 1.0).collect();
            luts.insert(b, lut);
        }
        GroupStore::from_layer_major(&planes, l, out, n_in, luts).unwrap()
    }

    #[test]
    fn dequant_matches_spec() {
        let s = toy_store();
        for bits in 3..=6u8 {
            let t = s.dequant(0, bits).unwrap();
            for o in 0..2 {
                for j in 0..16 {
                    let code6 = ((j * 4 + o) % 64) as usize;
                    let code_b = code6 >> (6 - bits as usize);
                    let want = code_b as f32 + o as f32 * 100.0;
                    assert_eq!(t.at(&[o, j]), want, "bits={bits} o={o} j={j}");
                }
            }
        }
    }

    #[test]
    fn nested_prefix_property() {
        // dequant at b and b+1 must agree on the *cluster hierarchy*:
        // code_b == code_{b+1} >> 1 (checked via the identity LUT above).
        let s = toy_store();
        let t5 = s.dequant(0, 5).unwrap();
        let t6 = s.dequant(0, 6).unwrap();
        for o in 0..2 {
            for j in 0..16 {
                let c6 = (t6.at(&[o, j]) - o as f32 * 100.0) as usize;
                let c5 = (t5.at(&[o, j]) - o as f32 * 100.0) as usize;
                assert_eq!(c5, c6 >> 1);
            }
        }
    }

    /// Differential property: the word-level kernel (both entry points)
    /// must be bit-exact against the retained naive reference on random
    /// stores across every (L, out, in, bits).
    #[test]
    fn word_kernel_matches_reference_property() {
        for_each_seed(40, |rng| {
            let s = random_store(rng);
            for layer in 0..s.n_layers {
                for bits in MIN_BITS..=MAX_BITS {
                    let reference = s.dequant_reference(layer, bits).unwrap();
                    let fast = s.dequant(layer, bits).unwrap();
                    assert_eq!(reference.data, fast.data, "bits={bits} layer={layer}");
                    let mut into = vec![0f32; s.out_dim * s.in_dim];
                    s.dequant_into_serial(layer, bits, &mut into).unwrap();
                    assert_eq!(reference.data, into, "serial bits={bits}");
                }
            }
        });
    }

    /// Differential property for the incremental path: codes at 3 bits,
    /// refined one plane at a time, must reproduce the reference at every
    /// intermediate bitwidth.
    #[test]
    fn refine_path_matches_reference_property() {
        for_each_seed(40, |rng| {
            let s = random_store(rng);
            for layer in 0..s.n_layers {
                let n = s.out_dim * s.in_dim;
                let mut codes = Codes::new();
                let mut out = vec![0f32; n];
                s.dequant_codes_into(layer, MIN_BITS, &mut codes).unwrap();
                for bits in MIN_BITS..=MAX_BITS {
                    if bits > MIN_BITS {
                        s.refine_codes_into(layer, &mut codes).unwrap();
                    }
                    assert_eq!(codes.bits(), bits);
                    s.lut_map_into(layer, bits, &codes, &mut out).unwrap();
                    let reference = s.dequant_reference(layer, bits).unwrap();
                    assert_eq!(reference.data, out, "bits={bits} layer={layer}");
                }
            }
        });
    }

    /// The satellite fix pinned: mapping codes through a LUT of a
    /// *different* bitwidth must be refused — at lower LUT widths the old
    /// bare-slice API indexed in-bounds and silently corrupted weights.
    #[test]
    fn codes_bits_mismatch_rejected() {
        let s = toy_store();
        let mut codes = Codes::new();
        let mut out = vec![0f32; s.out_dim * s.in_dim];
        s.dequant_codes_into(0, 3, &mut codes).unwrap();
        for wrong in [4u8, 5, 6] {
            let err = s.lut_map_into(0, wrong, &codes, &mut out).unwrap_err();
            assert!(err.to_string().contains("refusing mismatched codes"),
                    "bits={wrong}: {err}");
        }
        // ...and the matching width still works.
        s.lut_map_into(0, 3, &codes, &mut out).unwrap();
        // Refined codes stop matching the old width.
        s.refine_codes_into(0, &mut codes).unwrap();
        assert!(s.lut_map_into(0, 3, &codes, &mut out).is_err());
        s.lut_map_into(0, 4, &codes, &mut out).unwrap();
    }

    /// A slab big enough to cross the parallel threshold must agree with
    /// the reference through the scoped-thread path too.
    #[test]
    fn parallel_rows_match_reference() {
        let mut rng = Rng::new(0xA11CE);
        let (l, out, n_in) = (1usize, 48usize, 2048usize);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        for b in planes.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            luts.insert(b, (0..l * out * w).map(|_| rng.f32()).collect());
        }
        let s = GroupStore::from_layer_major(&planes, l, out, n_in, luts).unwrap();
        assert!(out * n_in >= super::PAR_MIN_ELEMS);
        for bits in [3u8, 5] {
            let reference = s.dequant_reference(0, bits).unwrap();
            let mut fast = vec![0f32; out * n_in];
            s.dequant_into(0, bits, &mut fast).unwrap();
            assert_eq!(reference.data, fast, "bits={bits}");
        }
    }

    #[test]
    fn memory_accounting_monotone() {
        let s = toy_store();
        assert!(s.bytes_at(3) < s.bytes_at(4));
        assert!(s.bytes_at(5) < s.bytes_at(6));
    }

    #[test]
    fn dequant_stack_shapes() {
        let s = toy_store();
        let t = s.dequant_stack(&[4]).unwrap();
        assert_eq!(t.shape, vec![1, 2, 16]);
    }

    #[test]
    fn bad_inputs_rejected() {
        let s = toy_store();
        assert!(s.dequant(0, 2).is_err());
        assert!(s.dequant(0, 7).is_err());
        assert!(s.dequant(3, 4).is_err());
        assert!(s.dequant_stack(&[4, 4]).is_err());
        let mut short = vec![0f32; 3];
        assert!(s.dequant_into(0, 4, &mut short).is_err());
        let mut codes = Codes::new();
        // refine on an uninitialized buffer (bits = 0) is rejected
        assert!(s.refine_codes_into(0, &mut codes).is_err());
        s.dequant_codes_into(0, 6, &mut codes).unwrap();
        // refine past MAX_BITS is rejected
        assert!(s.refine_codes_into(0, &mut codes).is_err());
        s.dequant_codes_into(0, 4, &mut codes).unwrap();
        assert!(s.refine_codes_into(9, &mut codes).is_err());
    }

    /// Malformed inputs are rejected at construction, not at dequant time.
    #[test]
    fn constructor_rejects_malformed_stores() {
        let good = toy_store();
        assert!(good.validate().is_ok());
        let (l, out, n_in) = (1usize, 2usize, 16usize);
        let planes = vec![0u8; l * 6 * out * (n_in / 8)];
        let full_luts = || -> BTreeMap<u8, Vec<f32>> {
            (MIN_BITS..=MAX_BITS)
                .map(|b| (b, vec![0f32; l * out * (1usize << b)]))
                .collect()
        };

        // short plane buffer
        assert!(GroupStore::from_layer_major(&planes[..planes.len() - 1], l, out,
                                             n_in, full_luts()).is_err());
        // in_dim not a byte multiple
        assert!(GroupStore::from_layer_major(&planes, l, out, 12, full_luts())
            .is_err());
        // short lut
        let mut luts = full_luts();
        luts.get_mut(&4).unwrap().pop();
        assert!(GroupStore::from_layer_major(&planes, l, out, n_in, luts).is_err());
        // missing lut
        let mut luts = full_luts();
        luts.remove(&5);
        assert!(GroupStore::from_layer_major(&planes, l, out, n_in, luts).is_err());
    }

    /// Tier-sliced residency: a 4-bit slice serves 3–4 bits bit-identically
    /// and refuses 5–6 bits with the typed residency error.
    #[test]
    fn slice_enforces_residency() {
        let s = toy_store();
        let s4 = s.slice(4).unwrap();
        assert_eq!(s4.max_bits(), 4);
        for bits in [3u8, 4] {
            assert_eq!(s.dequant(0, bits).unwrap().data,
                       s4.dequant(0, bits).unwrap().data);
        }
        for bits in [5u8, 6] {
            let err = s4.dequant(0, bits).unwrap_err();
            assert!(err.to_string().contains("resident precision"), "{err}");
        }
        // refine beyond the slice is refused too
        let mut codes = Codes::new();
        s4.dequant_codes_into(0, 4, &mut codes).unwrap();
        assert!(s4.refine_codes_into(0, &mut codes).is_err());
        // a slice cannot widen
        assert!(s4.slice(6).is_err());
        assert!(s.slice(2).is_err());
        assert!(s.slice(7).is_err());
        // resident bytes shrink with the slice
        assert!(s4.resident_plane_bytes() < s.resident_plane_bytes());
        assert_eq!(s4.resident_lut_bytes(),
                   (3..=4u8).map(|b| 2 * (1usize << b) * 4).sum::<usize>());
    }
}
