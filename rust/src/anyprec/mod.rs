//! Any-precision weight store: bitplane-packed nested codes + per-bitwidth
//! centroid tables (the Rust twin of the format defined in
//! `python/compile/kernels/ref.py` and produced by `quantize.py`).
//!
//! The store holds ONE copy of the 6-bit codes; every bitwidth 3..6 is a
//! view over the top-b planes — this is the memory-overlay property of
//! Any-Precision LLM that makes runtime adaptation feasible on-device.
//! The coordinator uses this module to *materialize* per-configuration
//! `W_l` / `W_h` stacks at model-load time (config switch, not request
//! path), and to account memory for Table 9.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::npz::{load_npz, NpyArray};

pub const GROUPS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];
pub const MIN_BITS: u8 = 3;
pub const MAX_BITS: u8 = 6;

/// Packed planes + LUTs for one linear group (stacked over layers).
pub struct GroupStore {
    /// u8 planes `[L, 6, out, in/8]` (plane 0 = MSB).
    pub planes: Vec<u8>,
    pub n_layers: usize,
    pub out_dim: usize,
    pub in_dim: usize,
    /// LUT per bitwidth b (3..=6): f32 `[L, out, 2^b]`.
    pub luts: BTreeMap<u8, Vec<f32>>,
}

impl GroupStore {
    fn plane_stride(&self) -> (usize, usize, usize) {
        let bytes_in = self.in_dim / 8;
        // strides for [L, 6, out, in/8]
        (6 * self.out_dim * bytes_in, self.out_dim * bytes_in, bytes_in)
    }

    /// Dequantize one layer at `bits` into a `[out, in]` tensor.
    pub fn dequant(&self, layer: usize, bits: u8) -> Result<Tensor> {
        if !(MIN_BITS..=MAX_BITS).contains(&bits) {
            bail!("bits {bits} out of range");
        }
        if layer >= self.n_layers {
            bail!("layer {layer} out of range ({})", self.n_layers);
        }
        let (sl, sp, so) = self.plane_stride();
        let bytes_in = self.in_dim / 8;
        let lut = self
            .luts
            .get(&bits)
            .ok_or_else(|| anyhow!("missing lut for {bits} bits"))?;
        let lut_w = 1usize << bits;
        let lut_base = layer * self.out_dim * lut_w;
        let mut out = vec![0f32; self.out_dim * self.in_dim];
        for o in 0..self.out_dim {
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let dst = &mut out[o * self.in_dim..(o + 1) * self.in_dim];
            for byte in 0..bytes_in {
                // gather the byte of each of the top `bits` planes
                let mut plane_bytes = [0u8; 6];
                for (p, pb) in plane_bytes.iter_mut().enumerate().take(bits as usize) {
                    *pb = self.planes[layer * sl + p * sp + o * so + byte];
                }
                for j in 0..8 {
                    let mut code = 0usize;
                    for pb in plane_bytes.iter().take(bits as usize) {
                        code = (code << 1) | ((pb >> j) & 1) as usize;
                    }
                    dst[byte * 8 + j] = row_lut[code];
                }
            }
        }
        Tensor::new(vec![self.out_dim, self.in_dim], out)
    }

    /// Materialize the full `[L, out, in]` stack at per-layer bitwidths.
    pub fn dequant_stack(&self, bits_per_layer: &[u8]) -> Result<Tensor> {
        if bits_per_layer.len() != self.n_layers {
            bail!("need {} bit entries, got {}", self.n_layers, bits_per_layer.len());
        }
        let mut data = Vec::with_capacity(self.n_layers * self.out_dim * self.in_dim);
        for (layer, &b) in bits_per_layer.iter().enumerate() {
            data.extend_from_slice(&self.dequant(layer, b)?.data);
        }
        Tensor::new(vec![self.n_layers, self.out_dim, self.in_dim], data)
    }

    /// Bytes of packed storage actually touched at bitwidth `bits`
    /// (planes + LUT) — the memory-traffic model behind Tables 5/9.
    pub fn bytes_at(&self, bits: u8) -> usize {
        let planes = self.n_layers * bits as usize * self.out_dim * self.in_dim / 8;
        let lut = self.n_layers * self.out_dim * (1 << bits) * 4;
        planes + lut
    }
}

/// The full any-precision model store (7 groups).
pub struct AnyPrecStore {
    pub groups: BTreeMap<String, GroupStore>,
}

impl AnyPrecStore {
    pub fn load(path: &str) -> Result<AnyPrecStore> {
        let arrays = load_npz(path)?;
        let mut groups = BTreeMap::new();
        for g in GROUPS {
            let planes = arrays
                .get(&format!("planes_{g}"))
                .ok_or_else(|| anyhow!("missing planes_{g} in {path}"))?;
            let shape = &planes.shape; // [L, 6, out, in/8]
            if shape.len() != 4 || shape[1] != 6 {
                bail!("planes_{g}: unexpected shape {:?}", shape);
            }
            let (n_layers, out_dim, in_dim) = (shape[0], shape[2], shape[3] * 8);
            let mut luts = BTreeMap::new();
            for b in MIN_BITS..=MAX_BITS {
                let lut: &NpyArray = arrays
                    .get(&format!("lut{b}_{g}"))
                    .ok_or_else(|| anyhow!("missing lut{b}_{g}"))?;
                if lut.shape != vec![n_layers, out_dim, 1 << b] {
                    bail!("lut{b}_{g}: unexpected shape {:?}", lut.shape);
                }
                luts.insert(b, lut.to_f32());
            }
            groups.insert(
                g.to_string(),
                GroupStore {
                    planes: planes.as_u8().context(format!("planes_{g}"))?.to_vec(),
                    n_layers,
                    out_dim,
                    in_dim,
                    luts,
                },
            );
        }
        Ok(AnyPrecStore { groups })
    }

    pub fn group(&self, g: &str) -> Result<&GroupStore> {
        self.groups.get(g).ok_or_else(|| anyhow!("unknown group {g}"))
    }

    /// Total packed capacity at the given budget bitwidth (Table 9 rows).
    pub fn capacity_bytes(&self, bits: u8) -> usize {
        self.groups.values().map(|g| g.bytes_at(bits)).sum()
    }

    pub fn n_layers(&self) -> usize {
        self.groups.values().next().map(|g| g.n_layers).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny store by hand and check dequant against the format spec.
    fn toy_store() -> GroupStore {
        // 1 layer, 2 out rows, 8 in cols; code6 of (o=0) = col index*8+o... keep simple:
        // col j in row o has 6-bit code = (j + o) % 64.
        let (l, out, n_in) = (1usize, 2usize, 16usize);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        let code = |o: usize, j: usize| -> u8 { ((j * 4 + o) % 64) as u8 };
        for o in 0..out {
            for j in 0..n_in {
                let c = code(o, j);
                for p in 0..6 {
                    let bit = (c >> (5 - p)) & 1;
                    if bit == 1 {
                        let idx = p * out * (n_in / 8) + o * (n_in / 8) + j / 8;
                        planes[idx] |= 1 << (j % 8);
                    }
                }
            }
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            // lut[o][c] = c as f32 + o*100
            let mut lut = vec![0f32; l * out * w];
            for o in 0..out {
                for c in 0..w {
                    lut[o * w + c] = c as f32 + o as f32 * 100.0;
                }
            }
            luts.insert(b, lut);
        }
        GroupStore { planes, n_layers: l, out_dim: out, in_dim: n_in, luts }
    }

    #[test]
    fn dequant_matches_spec() {
        let s = toy_store();
        for bits in 3..=6u8 {
            let t = s.dequant(0, bits).unwrap();
            for o in 0..2 {
                for j in 0..16 {
                    let code6 = ((j * 4 + o) % 64) as usize;
                    let code_b = code6 >> (6 - bits as usize);
                    let want = code_b as f32 + o as f32 * 100.0;
                    assert_eq!(t.at(&[o, j]), want, "bits={bits} o={o} j={j}");
                }
            }
        }
    }

    #[test]
    fn nested_prefix_property() {
        // dequant at b and b+1 must agree on the *cluster hierarchy*:
        // code_b == code_{b+1} >> 1 (checked via the identity LUT above).
        let s = toy_store();
        let t5 = s.dequant(0, 5).unwrap();
        let t6 = s.dequant(0, 6).unwrap();
        for o in 0..2 {
            for j in 0..16 {
                let c6 = (t6.at(&[o, j]) - o as f32 * 100.0) as usize;
                let c5 = (t5.at(&[o, j]) - o as f32 * 100.0) as usize;
                assert_eq!(c5, c6 >> 1);
            }
        }
    }

    #[test]
    fn memory_accounting_monotone() {
        let s = toy_store();
        assert!(s.bytes_at(3) < s.bytes_at(4));
        assert!(s.bytes_at(5) < s.bytes_at(6));
    }

    #[test]
    fn dequant_stack_shapes() {
        let s = toy_store();
        let t = s.dequant_stack(&[4]).unwrap();
        assert_eq!(t.shape, vec![1, 2, 16]);
    }

    #[test]
    fn bad_inputs_rejected() {
        let s = toy_store();
        assert!(s.dequant(0, 2).is_err());
        assert!(s.dequant(0, 7).is_err());
        assert!(s.dequant(3, 4).is_err());
        assert!(s.dequant_stack(&[4, 4]).is_err());
    }
}
