//! Any-precision weight store: bitplane-packed nested codes + per-bitwidth
//! centroid tables (the Rust twin of the format defined in
//! `python/compile/kernels/ref.py` and produced by `quantize.py`).
//!
//! The store holds ONE copy of the 6-bit codes; every bitwidth 3..6 is a
//! view over the top-b planes — this is the memory-overlay property of
//! Any-Precision LLM that makes runtime adaptation feasible on-device.
//! The coordinator uses this module to *materialize* per-configuration
//! `W_l` / `W_h` stacks at model-load time and on precision rebinds
//! (config switch, not request path), and to account memory for Table 9.
//!
//! Materialization is the config-switch hot path (DESIGN.md §Perf), so the
//! dequantizer comes in three speeds:
//!
//! * [`GroupStore::dequant_into`] — the **word-level kernel**: each packed
//!   byte of each plane is spread across the 8 byte-lanes of a `u64` via a
//!   precomputed 256-entry table ([`SPREAD`]), so 8 codes materialize with
//!   `bits` table lookups + shifts instead of `8 × bits` single-bit
//!   extractions, with `std::thread::scope` row-parallelism for large
//!   slabs and no per-layer allocation;
//! * [`GroupStore::refine_codes_into`] — the **incremental path**: the
//!   nested-prefix property (`code_{b+1} = code_b << 1 | bit_b`) turns a
//!   b→b+1 re-materialization into a single-plane walk;
//! * [`GroupStore::dequant_reference`] — the original naive per-bit loop,
//!   retained as the differential-test oracle and bench baseline.

pub mod materialize;

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Context, Result};

use crate::tensor::Tensor;
use crate::util::npz::{load_npz, NpyArray};

pub const GROUPS: [&str; 7] = ["wq", "wk", "wv", "wo", "wg", "wu", "wd"];
pub const MIN_BITS: u8 = 3;
pub const MAX_BITS: u8 = 6;

/// Slabs below this element count dequantize on the calling thread; the
/// scoped-thread fan-out only pays off once the rows amortize spawn cost.
const PAR_MIN_ELEMS: usize = 1 << 16;

/// Byte → bit-spread table: byte lane `j` of `SPREAD[v]` holds bit `j` of
/// `v`.  ORing shifted spreads of the top `b` plane bytes assembles the 8
/// codes of one packed byte in `b` lookups; lanes never carry into each
/// other because codes stay < 2^6 < 2^7.
static SPREAD: [u64; 256] = build_spread();

const fn build_spread() -> [u64; 256] {
    let mut table = [0u64; 256];
    let mut v = 0usize;
    while v < 256 {
        let mut j = 0;
        let mut acc = 0u64;
        while j < 8 {
            acc |= (((v >> j) & 1) as u64) << (8 * j);
            j += 1;
        }
        table[v] = acc;
        v += 1;
    }
    table
}

/// Assemble the 8 codes of packed-byte column `byte` from MSB-first plane
/// rows: lane `j` of the result is the code of element `byte*8 + j`.  The
/// single word-assembly step shared by the dequant and codes paths — keep
/// the packing convention in exactly one place.
#[inline(always)]
fn gather_codes(prows: &[&[u8]], byte: usize) -> u64 {
    let mut codes = 0u64;
    for prow in prows {
        codes = (codes << 1) | SPREAD[prow[byte] as usize];
    }
    codes
}

/// Packed planes + LUTs for one linear group (stacked over layers).
pub struct GroupStore {
    /// u8 planes `[L, 6, out, in/8]` (plane 0 = MSB).
    pub planes: Vec<u8>,
    pub n_layers: usize,
    pub out_dim: usize,
    pub in_dim: usize,
    /// LUT per bitwidth b (3..=6): f32 `[L, out, 2^b]`.
    pub luts: BTreeMap<u8, Vec<f32>>,
}

impl GroupStore {
    fn plane_stride(&self) -> (usize, usize, usize) {
        let bytes_in = self.in_dim / 8;
        // strides for [L, 6, out, in/8]
        (6 * self.out_dim * bytes_in, self.out_dim * bytes_in, bytes_in)
    }

    /// Structural invariants every dequant path assumes.  Run once at
    /// [`AnyPrecStore::load`] so a malformed npz fails loudly at load time
    /// instead of truncating or panicking mid-request.
    pub fn validate(&self) -> Result<()> {
        if self.n_layers == 0 || self.out_dim == 0 || self.in_dim == 0 {
            bail!(
                "degenerate store shape [L={}, out={}, in={}]",
                self.n_layers, self.out_dim, self.in_dim
            );
        }
        if self.in_dim % 8 != 0 {
            bail!("in_dim {} not a multiple of 8 (bitplane packing)", self.in_dim);
        }
        let want_planes = self.n_layers * 6 * self.out_dim * self.in_dim / 8;
        if self.planes.len() != want_planes {
            bail!(
                "plane buffer holds {} bytes, shape [L={}, 6, out={}, in/8={}] wants {}",
                self.planes.len(), self.n_layers, self.out_dim, self.in_dim / 8,
                want_planes
            );
        }
        for b in MIN_BITS..=MAX_BITS {
            let lut = self
                .luts
                .get(&b)
                .ok_or_else(|| anyhow!("missing lut for {b} bits"))?;
            let want = self.n_layers * self.out_dim * (1 << b);
            if lut.len() != want {
                bail!("lut{} holds {} entries, wants {}", b, lut.len(), want);
            }
        }
        Ok(())
    }

    fn check_layer_bits(&self, layer: usize, bits: u8) -> Result<&[f32]> {
        if !(MIN_BITS..=MAX_BITS).contains(&bits) {
            bail!("bits {bits} out of range");
        }
        if layer >= self.n_layers {
            bail!("layer {layer} out of range ({})", self.n_layers);
        }
        self.luts
            .get(&bits)
            .map(|v| v.as_slice())
            .ok_or_else(|| anyhow!("missing lut for {bits} bits"))
    }

    /// Word-level kernel core over rows `[row0, row0 + dst.len()/in_dim)`
    /// of one layer.  Preconditions (layer/bits/lut/length) are validated
    /// by the public entry points.  Dispatches to a bit-count-monomorphized
    /// body so the per-plane loop fully unrolls and the `lut_w - 1` mask
    /// provably bounds the LUT index (no per-element bounds check).
    fn dequant_rows(&self, layer: usize, bits: u8, lut: &[f32], row0: usize,
                    dst: &mut [f32]) {
        match bits {
            3 => self.dequant_rows_n::<3>(layer, lut, row0, dst),
            4 => self.dequant_rows_n::<4>(layer, lut, row0, dst),
            5 => self.dequant_rows_n::<5>(layer, lut, row0, dst),
            _ => self.dequant_rows_n::<6>(layer, lut, row0, dst),
        }
    }

    fn dequant_rows_n<const NB: usize>(&self, layer: usize, lut: &[f32],
                                       row0: usize, dst: &mut [f32]) {
        if self.in_dim == 0 {
            return; // degenerate hand-built store; load-time validate rejects
        }
        let (sl, sp, so) = self.plane_stride();
        let bytes_in = self.in_dim / 8;
        let lut_w = 1usize << NB;
        let lut_base = layer * self.out_dim * lut_w;
        let mask = lut_w - 1;
        let nrows = dst.len() / self.in_dim;
        for r in 0..nrows {
            let o = row0 + r;
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let row_dst = &mut dst[r * self.in_dim..(r + 1) * self.in_dim];
            let base = layer * sl + o * so;
            let prows: [&[u8]; NB] = std::array::from_fn(|p| {
                &self.planes[base + p * sp..base + p * sp + bytes_in]
            });
            for byte in 0..bytes_in {
                let codes = gather_codes(&prows, byte);
                let cell = &mut row_dst[byte * 8..byte * 8 + 8];
                for (j, c) in cell.iter_mut().enumerate() {
                    *c = row_lut[(codes >> (8 * j)) as usize & mask];
                }
            }
        }
    }

    /// Shared precondition check of the `dequant_into*` entry points:
    /// layer/bits in range, LUT present, destination exactly one slab.
    fn checked_lut(&self, layer: usize, bits: u8, out_len: usize) -> Result<&[f32]> {
        let lut = self.check_layer_bits(layer, bits)?;
        if out_len != self.out_dim * self.in_dim {
            bail!(
                "dequant_into buffer holds {} elements, layer wants {}",
                out_len, self.out_dim * self.in_dim
            );
        }
        Ok(lut)
    }

    /// Dequantize one layer at `bits` into caller-owned storage (the
    /// allocation-free variant) — word-level, single-threaded.
    pub fn dequant_into_serial(&self, layer: usize, bits: u8,
                               out: &mut [f32]) -> Result<()> {
        let lut = self.checked_lut(layer, bits, out.len())?;
        self.dequant_rows(layer, bits, lut, 0, out);
        Ok(())
    }

    /// [`GroupStore::dequant_into_serial`] with scoped-thread parallelism
    /// over `out_dim` rows for large slabs (no extra dependencies; small
    /// slabs stay on the calling thread).
    pub fn dequant_into(&self, layer: usize, bits: u8, out: &mut [f32]) -> Result<()> {
        let lut = self.checked_lut(layer, bits, out.len())?;
        let threads = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
            .min(self.out_dim);
        if threads <= 1 || out.len() < PAR_MIN_ELEMS {
            self.dequant_rows(layer, bits, lut, 0, out);
            return Ok(());
        }
        let rows_per = (self.out_dim + threads - 1) / threads;
        std::thread::scope(|s| {
            for (ci, chunk) in out.chunks_mut(rows_per * self.in_dim).enumerate() {
                s.spawn(move || self.dequant_rows(layer, bits, lut, ci * rows_per, chunk));
            }
        });
        Ok(())
    }

    /// Dequantize one layer at `bits` into a fresh `[out, in]` tensor.
    pub fn dequant(&self, layer: usize, bits: u8) -> Result<Tensor> {
        let mut out = vec![0f32; self.out_dim * self.in_dim];
        self.dequant_into(layer, bits, &mut out)?;
        Tensor::new(vec![self.out_dim, self.in_dim], out)
    }

    /// The original per-bit dequantizer, retained as the reference oracle
    /// for the differential property tests and the bench baseline.  Same
    /// semantics as [`GroupStore::dequant`], ~an order of magnitude slower.
    pub fn dequant_reference(&self, layer: usize, bits: u8) -> Result<Tensor> {
        let lut = self.check_layer_bits(layer, bits)?;
        let (sl, sp, so) = self.plane_stride();
        let bytes_in = self.in_dim / 8;
        let lut_w = 1usize << bits;
        let lut_base = layer * self.out_dim * lut_w;
        let mut out = vec![0f32; self.out_dim * self.in_dim];
        for o in 0..self.out_dim {
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let dst = &mut out[o * self.in_dim..(o + 1) * self.in_dim];
            for byte in 0..bytes_in {
                // gather the byte of each of the top `bits` planes
                let mut plane_bytes = [0u8; 6];
                for (p, pb) in plane_bytes.iter_mut().enumerate().take(bits as usize) {
                    *pb = self.planes[layer * sl + p * sp + o * so + byte];
                }
                for j in 0..8 {
                    let mut code = 0usize;
                    for pb in plane_bytes.iter().take(bits as usize) {
                        code = (code << 1) | ((pb >> j) & 1) as usize;
                    }
                    dst[byte * 8 + j] = row_lut[code];
                }
            }
        }
        Tensor::new(vec![self.out_dim, self.in_dim], out)
    }

    /// Materialize one layer's **codes** (not centroid values) at `bits`,
    /// word-level.  The codes buffer is the refinement state for
    /// [`GroupStore::refine_codes_into`].
    pub fn dequant_codes_into(&self, layer: usize, bits: u8,
                              codes: &mut [u8]) -> Result<()> {
        self.check_layer_bits(layer, bits)?;
        if codes.len() != self.out_dim * self.in_dim {
            bail!(
                "codes buffer holds {} elements, layer wants {}",
                codes.len(), self.out_dim * self.in_dim
            );
        }
        let (sl, sp, so) = self.plane_stride();
        let bytes_in = self.in_dim / 8;
        let nb = bits as usize;
        let empty: &[u8] = &[];
        for o in 0..self.out_dim {
            let row = &mut codes[o * self.in_dim..(o + 1) * self.in_dim];
            let base = layer * sl + o * so;
            let mut prows: [&[u8]; 6] = [empty; 6];
            for (p, slot) in prows.iter_mut().enumerate().take(nb) {
                *slot = &self.planes[base + p * sp..base + p * sp + bytes_in];
            }
            for byte in 0..bytes_in {
                let w = gather_codes(&prows[..nb], byte);
                let cell = &mut row[byte * 8..byte * 8 + 8];
                for (j, c) in cell.iter_mut().enumerate() {
                    *c = ((w >> (8 * j)) & 0x3f) as u8;
                }
            }
        }
        Ok(())
    }

    /// Incremental refinement `from_bits → from_bits + 1`: append the next
    /// plane's bit to every code (`code_{b+1} = code_b << 1 | bit_b`).
    /// Reads exactly ONE plane instead of re-walking all `b+1`, which is
    /// what makes sweeping 3→4→5→6 (calibration, candidate probing) cost
    /// one full dequant plus three single-plane passes.
    pub fn refine_codes_into(&self, layer: usize, from_bits: u8,
                             codes: &mut [u8]) -> Result<()> {
        if !(MIN_BITS..MAX_BITS).contains(&from_bits) {
            bail!("refine from {from_bits} bits: need {MIN_BITS}..{}", MAX_BITS - 1);
        }
        if layer >= self.n_layers {
            bail!("layer {layer} out of range ({})", self.n_layers);
        }
        if codes.len() != self.out_dim * self.in_dim {
            bail!(
                "codes buffer holds {} elements, layer wants {}",
                codes.len(), self.out_dim * self.in_dim
            );
        }
        let (sl, sp, so) = self.plane_stride();
        let bytes_in = self.in_dim / 8;
        let p = from_bits as usize; // planes 0..from_bits gave the prefix
        for o in 0..self.out_dim {
            let row = &mut codes[o * self.in_dim..(o + 1) * self.in_dim];
            let base = layer * sl + p * sp + o * so;
            for byte in 0..bytes_in {
                let pb = self.planes[base + byte];
                let cell = &mut row[byte * 8..byte * 8 + 8];
                for (j, c) in cell.iter_mut().enumerate() {
                    *c = (*c << 1) | ((pb >> j) & 1);
                }
            }
        }
        Ok(())
    }

    /// Map a codes buffer at `bits` through the layer's LUT.  Codes must
    /// have been produced at exactly `bits` (dequant_codes_into / refined
    /// to it).  Mismatches are NOT detectable here: codes at *higher*
    /// bitwidths index past the LUT row and panic, but codes at *lower*
    /// bitwidths index in-bounds and silently yield wrong weights — the
    /// caller owns tracking the codes' current bitwidth.
    pub fn lut_map_into(&self, layer: usize, bits: u8, codes: &[u8],
                        out: &mut [f32]) -> Result<()> {
        let lut = self.check_layer_bits(layer, bits)?;
        let n = self.out_dim * self.in_dim;
        if codes.len() != n || out.len() != n {
            bail!("lut_map buffers hold {}/{} elements, layer wants {n}",
                  codes.len(), out.len());
        }
        let lut_w = 1usize << bits;
        let lut_base = layer * self.out_dim * lut_w;
        for o in 0..self.out_dim {
            let row_lut = &lut[lut_base + o * lut_w..lut_base + (o + 1) * lut_w];
            let src = &codes[o * self.in_dim..(o + 1) * self.in_dim];
            let dst = &mut out[o * self.in_dim..(o + 1) * self.in_dim];
            for (d, &c) in dst.iter_mut().zip(src) {
                *d = row_lut[c as usize];
            }
        }
        Ok(())
    }

    /// Materialize the full `[L, out, in]` stack at per-layer bitwidths
    /// into one allocation (word-level per layer).
    pub fn dequant_stack(&self, bits_per_layer: &[u8]) -> Result<Tensor> {
        if bits_per_layer.len() != self.n_layers {
            bail!("need {} bit entries, got {}", self.n_layers, bits_per_layer.len());
        }
        let n = self.out_dim * self.in_dim;
        let mut data = vec![0f32; self.n_layers * n];
        for ((layer, &b), chunk) in
            bits_per_layer.iter().enumerate().zip(data.chunks_mut(n))
        {
            self.dequant_into(layer, b, chunk)?;
        }
        Tensor::new(vec![self.n_layers, self.out_dim, self.in_dim], data)
    }

    /// Bytes of packed storage actually touched at bitwidth `bits`
    /// (planes + LUT) — the memory-traffic model behind Tables 5/9.
    pub fn bytes_at(&self, bits: u8) -> usize {
        let planes = self.n_layers * bits as usize * self.out_dim * self.in_dim / 8;
        let lut = self.n_layers * self.out_dim * (1 << bits) * 4;
        planes + lut
    }

    /// Host bytes of one materialized layer slab (`[out, in]` f32).
    pub fn layer_slab_bytes(&self) -> usize {
        self.out_dim * self.in_dim * 4
    }
}

/// The full any-precision model store (7 groups).
pub struct AnyPrecStore {
    pub groups: BTreeMap<String, GroupStore>,
}

impl AnyPrecStore {
    pub fn load(path: &str) -> Result<AnyPrecStore> {
        let arrays = load_npz(path)?;
        let mut groups = BTreeMap::new();
        for g in GROUPS {
            let planes = arrays
                .get(&format!("planes_{g}"))
                .ok_or_else(|| anyhow!("missing planes_{g} in {path}"))?;
            let shape = &planes.shape; // [L, 6, out, in/8]
            if shape.len() != 4 || shape[1] != 6 {
                bail!("planes_{g}: unexpected shape {:?}", shape);
            }
            let (n_layers, out_dim, in_dim) = (shape[0], shape[2], shape[3] * 8);
            let mut luts = BTreeMap::new();
            for b in MIN_BITS..=MAX_BITS {
                let lut: &NpyArray = arrays
                    .get(&format!("lut{b}_{g}"))
                    .ok_or_else(|| anyhow!("missing lut{b}_{g}"))?;
                if lut.shape != vec![n_layers, out_dim, 1 << b] {
                    bail!("lut{b}_{g}: unexpected shape {:?}", lut.shape);
                }
                luts.insert(b, lut.to_f32());
            }
            let store = GroupStore {
                planes: planes.as_u8().context(format!("planes_{g}"))?.to_vec(),
                n_layers,
                out_dim,
                in_dim,
                luts,
            };
            store
                .validate()
                .with_context(|| format!("planes_{g} in {path}"))?;
            groups.insert(g.to_string(), store);
        }
        Ok(AnyPrecStore { groups })
    }

    pub fn group(&self, g: &str) -> Result<&GroupStore> {
        self.groups.get(g).ok_or_else(|| anyhow!("unknown group {g}"))
    }

    /// Total packed capacity at the given budget bitwidth (Table 9 rows).
    pub fn capacity_bytes(&self, bits: u8) -> usize {
        self.groups.values().map(|g| g.bytes_at(bits)).sum()
    }

    pub fn n_layers(&self) -> usize {
        self.groups.values().next().map(|g| g.n_layers).unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::{for_each_seed, Rng};

    /// Build a tiny store by hand and check dequant against the format spec.
    fn toy_store() -> GroupStore {
        // 1 layer, 2 out rows, 8 in cols; code6 of (o=0) = col index*8+o... keep simple:
        // col j in row o has 6-bit code = (j + o) % 64.
        let (l, out, n_in) = (1usize, 2usize, 16usize);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        let code = |o: usize, j: usize| -> u8 { ((j * 4 + o) % 64) as u8 };
        for o in 0..out {
            for j in 0..n_in {
                let c = code(o, j);
                for p in 0..6 {
                    let bit = (c >> (5 - p)) & 1;
                    if bit == 1 {
                        let idx = p * out * (n_in / 8) + o * (n_in / 8) + j / 8;
                        planes[idx] |= 1 << (j % 8);
                    }
                }
            }
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            // lut[o][c] = c as f32 + o*100
            let mut lut = vec![0f32; l * out * w];
            for o in 0..out {
                for c in 0..w {
                    lut[o * w + c] = c as f32 + o as f32 * 100.0;
                }
            }
            luts.insert(b, lut);
        }
        GroupStore { planes, n_layers: l, out_dim: out, in_dim: n_in, luts }
    }

    /// Random store with arbitrary codes and LUT values (dims vary).
    fn random_store(rng: &mut Rng) -> GroupStore {
        let l = rng.range(1, 4);
        let out = rng.range(1, 6);
        let n_in = 8 * rng.range(1, 5);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        for b in planes.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            let lut: Vec<f32> =
                (0..l * out * w).map(|_| rng.f32() * 2.0 - 1.0).collect();
            luts.insert(b, lut);
        }
        GroupStore { planes, n_layers: l, out_dim: out, in_dim: n_in, luts }
    }

    #[test]
    fn dequant_matches_spec() {
        let s = toy_store();
        for bits in 3..=6u8 {
            let t = s.dequant(0, bits).unwrap();
            for o in 0..2 {
                for j in 0..16 {
                    let code6 = ((j * 4 + o) % 64) as usize;
                    let code_b = code6 >> (6 - bits as usize);
                    let want = code_b as f32 + o as f32 * 100.0;
                    assert_eq!(t.at(&[o, j]), want, "bits={bits} o={o} j={j}");
                }
            }
        }
    }

    #[test]
    fn nested_prefix_property() {
        // dequant at b and b+1 must agree on the *cluster hierarchy*:
        // code_b == code_{b+1} >> 1 (checked via the identity LUT above).
        let s = toy_store();
        let t5 = s.dequant(0, 5).unwrap();
        let t6 = s.dequant(0, 6).unwrap();
        for o in 0..2 {
            for j in 0..16 {
                let c6 = (t6.at(&[o, j]) - o as f32 * 100.0) as usize;
                let c5 = (t5.at(&[o, j]) - o as f32 * 100.0) as usize;
                assert_eq!(c5, c6 >> 1);
            }
        }
    }

    /// Differential property: the word-level kernel (both entry points)
    /// must be bit-exact against the retained naive reference on random
    /// stores across every (L, out, in, bits).
    #[test]
    fn word_kernel_matches_reference_property() {
        for_each_seed(40, |rng| {
            let s = random_store(rng);
            for layer in 0..s.n_layers {
                for bits in MIN_BITS..=MAX_BITS {
                    let reference = s.dequant_reference(layer, bits).unwrap();
                    let fast = s.dequant(layer, bits).unwrap();
                    assert_eq!(reference.data, fast.data, "bits={bits} layer={layer}");
                    let mut into = vec![0f32; s.out_dim * s.in_dim];
                    s.dequant_into_serial(layer, bits, &mut into).unwrap();
                    assert_eq!(reference.data, into, "serial bits={bits}");
                }
            }
        });
    }

    /// Differential property for the incremental path: codes at 3 bits,
    /// refined one plane at a time, must reproduce the reference at every
    /// intermediate bitwidth.
    #[test]
    fn refine_path_matches_reference_property() {
        for_each_seed(40, |rng| {
            let s = random_store(rng);
            for layer in 0..s.n_layers {
                let n = s.out_dim * s.in_dim;
                let mut codes = vec![0u8; n];
                let mut out = vec![0f32; n];
                s.dequant_codes_into(layer, MIN_BITS, &mut codes).unwrap();
                for bits in MIN_BITS..=MAX_BITS {
                    if bits > MIN_BITS {
                        s.refine_codes_into(layer, bits - 1, &mut codes).unwrap();
                    }
                    s.lut_map_into(layer, bits, &codes, &mut out).unwrap();
                    let reference = s.dequant_reference(layer, bits).unwrap();
                    assert_eq!(reference.data, out, "bits={bits} layer={layer}");
                }
            }
        });
    }

    /// A slab big enough to cross the parallel threshold must agree with
    /// the reference through the scoped-thread path too.
    #[test]
    fn parallel_rows_match_reference() {
        let mut rng = Rng::new(0xA11CE);
        let (l, out, n_in) = (1usize, 48usize, 2048usize);
        let mut planes = vec![0u8; l * 6 * out * (n_in / 8)];
        for b in planes.iter_mut() {
            *b = rng.next_u64() as u8;
        }
        let mut luts = BTreeMap::new();
        for b in MIN_BITS..=MAX_BITS {
            let w = 1usize << b;
            luts.insert(b, (0..l * out * w).map(|_| rng.f32()).collect());
        }
        let s = GroupStore { planes, n_layers: l, out_dim: out, in_dim: n_in, luts };
        assert!(out * n_in >= super::PAR_MIN_ELEMS);
        for bits in [3u8, 5] {
            let reference = s.dequant_reference(0, bits).unwrap();
            let mut fast = vec![0f32; out * n_in];
            s.dequant_into(0, bits, &mut fast).unwrap();
            assert_eq!(reference.data, fast, "bits={bits}");
        }
    }

    #[test]
    fn memory_accounting_monotone() {
        let s = toy_store();
        assert!(s.bytes_at(3) < s.bytes_at(4));
        assert!(s.bytes_at(5) < s.bytes_at(6));
    }

    #[test]
    fn dequant_stack_shapes() {
        let s = toy_store();
        let t = s.dequant_stack(&[4]).unwrap();
        assert_eq!(t.shape, vec![1, 2, 16]);
    }

    #[test]
    fn bad_inputs_rejected() {
        let s = toy_store();
        assert!(s.dequant(0, 2).is_err());
        assert!(s.dequant(0, 7).is_err());
        assert!(s.dequant(3, 4).is_err());
        assert!(s.dequant_stack(&[4, 4]).is_err());
        let mut short = vec![0f32; 3];
        assert!(s.dequant_into(0, 4, &mut short).is_err());
        let mut codes = vec![0u8; 2 * 16];
        assert!(s.refine_codes_into(0, 6, &mut codes).is_err());
        assert!(s.refine_codes_into(0, 2, &mut codes).is_err());
        assert!(s.refine_codes_into(9, 4, &mut codes).is_err());
    }

    #[test]
    fn validate_catches_malformed_stores() {
        let s = toy_store();
        assert!(s.validate().is_ok());

        let mut truncated = toy_store();
        truncated.planes.pop();
        assert!(truncated.validate().is_err(), "short plane buffer accepted");

        let mut ragged_in = toy_store();
        ragged_in.in_dim = 12; // not a byte multiple
        assert!(ragged_in.validate().is_err(), "in_dim % 8 != 0 accepted");

        let mut bad_lut = toy_store();
        bad_lut.luts.get_mut(&4).unwrap().pop();
        assert!(bad_lut.validate().is_err(), "short lut accepted");

        let mut missing_lut = toy_store();
        missing_lut.luts.remove(&5);
        assert!(missing_lut.validate().is_err(), "missing lut accepted");
    }
}
