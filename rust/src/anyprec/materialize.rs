//! Byte-budgeted LRU cache of materialized weight slabs.
//!
//! DP-LLM changes per-layer precision at runtime; every change used to pay
//! a full re-dequantization and re-upload of all 7 × L × {wl, wh} stacks
//! even when one layer flipped bits.  This cache makes precision switching
//! incremental: one entry per (group, layer, bits) holds the host f32 slab
//! AND the device buffer it was uploaded to, so a rebind touches only the
//! layers whose assignment actually changed (DESIGN.md §Perf, delta-rebind
//! protocol).  The type is generic over the device-buffer payload `B` so
//! the LRU/accounting logic is unit-testable without a PJRT device
//! (`B = ()`); the runtime instantiates it with `B = PjRtBuffer`.
//!
//! Budget semantics: `budget_bytes` caps the **host** slab bytes resident
//! in the cache (the device mirrors are 1:1, so device residency is
//! bounded by the same figure).  Eviction is strict LRU.  A single slab
//! larger than the whole budget is still admitted — the materializer must
//! be able to serve it — leaving the cache transiently over budget until
//! the next insert evicts it.
//!
//! Counters (hits / misses / evictions / bytes dequantized) are exposed
//! via [`MaterializeCache::snapshot`] next to the host↔device meters of
//! `Runtime::transfers()`; the O(k)-rebind tests assert through both.

use std::collections::HashMap;
use std::rc::Rc;

use anyhow::Result;

/// One (group, layer, bits) materialization.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct MatKey {
    pub group: String,
    pub layer: usize,
    pub bits: u8,
}

struct MatEntry<B> {
    host: Rc<Vec<f32>>,
    device: Rc<B>,
    bytes: usize,
    stamp: u64,
}

/// Point-in-time counters of a [`MaterializeCache`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MatSnapshot {
    pub hits: u64,
    pub misses: u64,
    pub evictions: u64,
    /// Host bytes produced by dequantization (misses only).
    pub bytes_dequantized: u64,
    /// Host bytes currently resident.
    pub resident_bytes: usize,
    pub entries: usize,
}

pub struct MaterializeCache<B> {
    map: HashMap<MatKey, MatEntry<B>>,
    budget: usize,
    resident: usize,
    clock: u64,
    hits: u64,
    misses: u64,
    evictions: u64,
    bytes_dequantized: u64,
}

impl<B> MaterializeCache<B> {
    pub fn new(budget_bytes: usize) -> MaterializeCache<B> {
        MaterializeCache {
            map: HashMap::new(),
            budget: budget_bytes,
            resident: 0,
            clock: 0,
            hits: 0,
            misses: 0,
            evictions: 0,
            bytes_dequantized: 0,
        }
    }

    pub fn budget_bytes(&self) -> usize {
        self.budget
    }

    pub fn contains(&self, key: &MatKey) -> bool {
        self.map.contains_key(key)
    }

    /// Look up `key`; on miss, run `make` (dequantize + upload) and admit
    /// the result, evicting LRU entries past the byte budget.  Returns the
    /// host slab and device buffer — `Rc`s, so an evicted-but-still-in-use
    /// slab stays alive for its holder and frees when the last user drops.
    pub fn get_or_materialize(
        &mut self,
        key: &MatKey,
        make: impl FnOnce(&MatKey) -> Result<(Vec<f32>, B)>,
    ) -> Result<(Rc<Vec<f32>>, Rc<B>)> {
        self.clock += 1;
        if let Some(e) = self.map.get_mut(key) {
            e.stamp = self.clock;
            self.hits += 1;
            return Ok((e.host.clone(), e.device.clone()));
        }
        let (host, device) = make(key)?;
        let bytes = host.len() * 4;
        self.misses += 1;
        self.bytes_dequantized += bytes as u64;
        self.evict_to_fit(bytes);
        let entry = MatEntry {
            host: Rc::new(host),
            device: Rc::new(device),
            bytes,
            stamp: self.clock,
        };
        let out = (entry.host.clone(), entry.device.clone());
        self.resident += bytes;
        self.map.insert(key.clone(), entry);
        Ok(out)
    }

    fn evict_to_fit(&mut self, incoming: usize) {
        while self.resident + incoming > self.budget && !self.map.is_empty() {
            let victim = self
                .map
                .iter()
                .min_by_key(|(_, e)| e.stamp)
                .map(|(k, _)| k.clone())
                .expect("nonempty map has a minimum");
            let e = self.map.remove(&victim).expect("victim present");
            self.resident -= e.bytes;
            self.evictions += 1;
        }
    }

    pub fn snapshot(&self) -> MatSnapshot {
        MatSnapshot {
            hits: self.hits,
            misses: self.misses,
            evictions: self.evictions,
            bytes_dequantized: self.bytes_dequantized,
            resident_bytes: self.resident,
            entries: self.map.len(),
        }
    }
}

/// Indices where two per-layer bit assignments differ — the layers a
/// delta rebind must re-materialize.
pub fn changed_layers(old: &[u8], new: &[u8]) -> Vec<usize> {
    old.iter()
        .zip(new)
        .enumerate()
        .filter(|(_, (a, b))| a != b)
        .map(|(i, _)| i)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SLAB: usize = 64; // f32 elements per test slab (256 bytes)

    fn key(layer: usize, bits: u8) -> MatKey {
        MatKey { group: "wq".into(), layer, bits }
    }

    fn fill(c: &mut MaterializeCache<()>, layer: usize, bits: u8) {
        c.get_or_materialize(&key(layer, bits), |_| Ok((vec![0f32; SLAB], ())))
            .unwrap();
    }

    #[test]
    fn hit_on_unchanged_key_skips_materialization() {
        let mut c = MaterializeCache::<()>::new(1 << 20);
        fill(&mut c, 0, 4);
        let (host, _) = c
            .get_or_materialize(&key(0, 4), |_| {
                panic!("cache hit must not re-materialize")
            })
            .unwrap();
        assert_eq!(host.len(), SLAB);
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses), (1, 1));
        assert_eq!(s.bytes_dequantized, (SLAB * 4) as u64);
    }

    #[test]
    fn same_layer_different_bits_is_a_distinct_entry() {
        let mut c = MaterializeCache::<()>::new(1 << 20);
        fill(&mut c, 0, 3);
        fill(&mut c, 0, 4);
        let s = c.snapshot();
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 2));
    }

    #[test]
    fn lru_eviction_respects_byte_budget() {
        let bytes = SLAB * 4;
        let mut c = MaterializeCache::<()>::new(3 * bytes);
        fill(&mut c, 0, 4);
        fill(&mut c, 1, 4);
        fill(&mut c, 2, 4);
        assert_eq!(c.snapshot().entries, 3);
        // Touch layer 0 so layer 1 becomes LRU, then overflow.
        fill(&mut c, 0, 4);
        fill(&mut c, 3, 4);
        let s = c.snapshot();
        assert!(s.resident_bytes <= c.budget_bytes(), "over budget: {s:?}");
        assert_eq!(s.entries, 3);
        assert_eq!(s.evictions, 1);
        assert!(c.contains(&key(0, 4)), "recently-touched entry evicted");
        assert!(!c.contains(&key(1, 4)), "LRU entry survived");
        assert!(c.contains(&key(2, 4)) && c.contains(&key(3, 4)));
    }

    #[test]
    fn oversized_slab_still_admitted() {
        let mut c = MaterializeCache::<()>::new(8); // budget below one slab
        fill(&mut c, 0, 4);
        assert_eq!(c.snapshot().entries, 1, "materializer must still serve");
        fill(&mut c, 1, 4);
        let s = c.snapshot();
        assert_eq!(s.entries, 1, "previous oversized entry must be evicted");
        assert_eq!(s.evictions, 1);
    }

    /// The counter-based delta-rebind property: re-materializing a stack
    /// after k of L layers changed bits runs the dequantizer for exactly
    /// the k changed layers — everything else is a cache hit, i.e. O(k)
    /// work and O(k) fresh uploads, not O(L).
    #[test]
    fn delta_rebind_rematerializes_exactly_changed_layers() {
        let l = 12usize;
        let old_bits = vec![4u8; l];
        let mut new_bits = old_bits.clone();
        new_bits[2] = 5;
        new_bits[7] = 3;
        new_bits[11] = 6;
        let k = changed_layers(&old_bits, &new_bits).len();
        assert_eq!(k, 3);

        let mut c = MaterializeCache::<()>::new(1 << 20);
        let mut materializations = 0usize;
        let mut stack = |cache: &mut MaterializeCache<()>, bits: &[u8],
                         count: &mut usize| {
            for (layer, &b) in bits.iter().enumerate() {
                cache
                    .get_or_materialize(&key(layer, b), |_| {
                        *count += 1;
                        Ok((vec![0f32; SLAB], ()))
                    })
                    .unwrap();
            }
        };
        stack(&mut c, &old_bits, &mut materializations);
        assert_eq!(materializations, l);
        let before = c.snapshot();

        // The rebind: only the 3 changed layers materialize afresh.
        stack(&mut c, &new_bits, &mut materializations);
        let after = c.snapshot();
        assert_eq!(materializations, l + k, "re-dequantized an unchanged layer");
        assert_eq!(after.misses - before.misses, k as u64);
        assert_eq!(after.hits - before.hits, (l - k) as u64);
        assert_eq!(
            after.bytes_dequantized - before.bytes_dequantized,
            (k * SLAB * 4) as u64,
            "rebind dequantized O(L), not O(k), bytes"
        );
    }

    #[test]
    fn changed_layers_diff() {
        assert_eq!(changed_layers(&[3, 4, 5], &[3, 4, 5]), Vec::<usize>::new());
        assert_eq!(changed_layers(&[3, 4, 5], &[4, 4, 6]), vec![0, 2]);
        assert_eq!(changed_layers(&[], &[]), Vec::<usize>::new());
    }
}
