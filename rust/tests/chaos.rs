//! Chaos scenario suite (DESIGN.md §Evaluation): hermetic regression
//! gates — `cargo test -q`, no `DPLLM_ARTIFACTS` — that drive the REAL
//! serving code (router, pool accounting, downshift policy) through the
//! faults production will see, and pin the counters each fault must
//! move.  Every injected request must reach exactly one terminal
//! outcome; "the fleet got wedged" is itself a failure (wall deadlines).
//!
//! The four scenarios and their counters:
//! 1. poisoned prompts (oversized/empty) mid-burst →
//!    `router_rejects_invalid` (the fleet aggregate of the core's
//!    `admit_rejects_invalid` 400 shape)
//! 2. `reconfigure()` retiring a target under load →
//!    `prefix_invalidations` on the KV pool (the exact call
//!    `ServingEngine::reconfigure` makes for each retired tag)
//! 3. replica kill/respawn mid-trace → `router_respawns`, with the
//!    no-healthy-request-lost invariant
//! 4. KV-pressure downshift under a sustained burst → the
//!    `downshift_for_pressure` policy (the core's `admit_downshifts`
//!    path) over real pool pressure accounting
//! 5. fleet-event ordering in the flight recorder → a killed replica's
//!    `drain` trace event precedes its `respawn`, straight from the
//!    same global tracer `GET /trace` serves

use std::rc::Rc;
use std::time::Duration;

use dp_llm::coordinator::loadgen::{
    replay_fleet, ArrivalProcess, ReplayOpts, TraceSpec,
};
use dp_llm::coordinator::router::{Router, RouterConfig, RouterEvent};
use dp_llm::runtime::replica::sim::{sim_link, SimProfile};
use dp_llm::runtime::replica::ReplicaSpec;

const TOKEN_US: u64 = 50;

fn burst() -> ArrivalProcess {
    ArrivalProcess::Bursty {
        rate_on: 300.0,
        rate_off: 10.0,
        mean_on_s: 0.5,
        mean_off_s: 0.5,
    }
}

fn two_replicas(profile_for: impl Fn(usize) -> SimProfile + 'static)
                -> Router {
    let specs = vec![
        ReplicaSpec::sim(0, &["3.25", "3.50"], false, TOKEN_US as f64 / 1e3),
        ReplicaSpec::sim(1, &["4.50", "4.75"], true, TOKEN_US as f64 / 1e3),
    ];
    Router::new(
        specs,
        Box::new(move |spec| sim_link(spec, profile_for(spec.id))),
        RouterConfig::default(),
    )
}

/// Drive the router until `want` terminal events or the deadline.
fn drive(router: &mut Router, want: usize, deadline: Duration)
         -> Vec<RouterEvent> {
    let start = std::time::Instant::now();
    let mut out = Vec::new();
    let mut terminal = 0usize;
    while terminal < want {
        assert!(
            start.elapsed() < deadline,
            "fleet wedged: {terminal}/{want} terminal after {deadline:?}"
        );
        for ev in router.poll() {
            match ev {
                RouterEvent::Done { .. }
                | RouterEvent::Failed { .. }
                | RouterEvent::Rejected { .. } => terminal += 1,
                RouterEvent::Respawned { .. } => {}
            }
            out.push(ev);
        }
        std::thread::sleep(Duration::from_micros(100));
    }
    out
}

/// Chaos 1 — poisoned prompts mid-burst.  A bursty trace is replayed at
/// saturation with every 5th request poisoned (alternately empty and
/// oversized).  Sim replicas screen admission like the engine core
/// (`max_prompt_chars`); the router must surface each poison as a
/// terminal 400-shaped reject, count it in `router_rejects_invalid`,
/// and finish every healthy request untouched.
#[test]
fn poisoned_prompts_mid_burst_terminal_and_counted() {
    const N: usize = 60;
    const MAX_PROMPT_CHARS: usize = 512;
    let spec = TraceSpec::mixed(burst(), 128, 8);
    let trace = spec.generate(N, 23).unwrap();
    let mut router = two_replicas(|_| SimProfile {
        token_us: TOKEN_US,
        max_prompt_chars: Some(MAX_PROMPT_CHARS),
        ..SimProfile::default()
    });
    let mut poisoned = Vec::new();
    for i in 0..N {
        let mut req = trace.request(i);
        if i % 5 == 2 {
            // Alternate the two poison shapes the core screens for.
            req.prompt = if i % 10 == 2 {
                String::new()
            } else {
                "x".repeat(MAX_PROMPT_CHARS + 1)
            };
            poisoned.push(i as u64);
        }
        assert!(
            router.submit(req, None).is_none(),
            "unexpected immediate reject from an unsaturated fleet"
        );
    }
    let events = drive(&mut router, N, Duration::from_secs(20));
    let mut invalid_ids = Vec::new();
    let (mut done, mut failed) = (0usize, 0usize);
    for ev in &events {
        match ev {
            RouterEvent::Done { .. } => done += 1,
            RouterEvent::Failed { .. } => failed += 1,
            RouterEvent::Rejected { id, capacity, .. } => {
                assert!(!*capacity, "poison surfaced as a retryable 503");
                invalid_ids.push(*id);
            }
            RouterEvent::Respawned { .. } => {}
        }
    }
    invalid_ids.sort_unstable();
    assert_eq!(invalid_ids, poisoned, "exactly the poisoned ids rejected");
    assert_eq!(done, N - poisoned.len(), "every healthy request completed");
    assert_eq!(failed, 0);
    let c = router.counters();
    assert_eq!(c.rejects_invalid, poisoned.len() as u64);
    assert_eq!(c.rejects_capacity, 0);
    router.shutdown();
}

/// Chaos 2 — `reconfigure()` under load with prefix-cache invalidation.
/// Drives the REAL pool accounting (unit buffers): prefixes published
/// under two target identities while live generations hold bytes, then
/// one target is retired exactly the way `ServingEngine::reconfigure`
/// does it — `invalidate_tag` per retired identity.  The retired tag's
/// entries must drop (counted by `prefix_invalidations`, not the LRU
/// `prefix_evictions`), the survivor's entries must keep hitting, and
/// byte accounting must stay exact.
#[test]
fn reconfigure_under_load_invalidates_retired_prefixes() {
    use dp_llm::runtime::kvpool::KvPool;
    const QUANTUM: usize = 16;
    let mut pool: KvPool<()> = KvPool::new(64 * 1024, 16);
    // Live load: four in-flight generations hold committed bytes.
    for _ in 0..4 {
        pool.charge(256).unwrap();
    }
    // Published prefixes under a retiring identity and a surviving one.
    let ids: Vec<u32> = (0..64u32).collect();
    for (t, len) in [(16usize, 16usize), (32, 32), (48, 48)] {
        pool.prefix_insert("m:4.50", &ids, len, t, Rc::new(()));
    }
    pool.prefix_insert("m:3.50", &ids, 32, 32, Rc::new(()));
    assert_eq!(pool.prefix_entries(), 4);
    let held = pool.prefix_bytes();
    assert!(held > 0);

    // The reconfigure() retire path, mid-load.
    let dropped = pool.invalidate_tag("m:4.50");
    assert_eq!(dropped, 3, "all three retired-tag entries dropped");
    assert_eq!(pool.prefix_invalidations, 3);
    assert_eq!(pool.prefix_evictions, 0, "invalidation is not LRU eviction");
    assert_eq!(pool.prefix_entries(), 1);
    assert!(pool.prefix_bytes() < held, "retired bytes reclaimed");

    // Retired identity can never hit again; the survivor still does.
    assert!(pool.prefix_lookup("m:4.50", &ids, QUANTUM).is_none());
    let hit = pool.prefix_lookup("m:3.50", &ids, QUANTUM).expect("live tag");
    assert_eq!(hit.len, 32);
    // Live generations were untouched.
    assert_eq!(pool.in_use_bytes(), 4 * 256 * 16);
    // Re-retiring is a no-op, not a counter leak.
    assert_eq!(pool.invalidate_tag("m:4.50"), 0);
    assert_eq!(pool.prefix_invalidations, 3);
}

/// Chaos 3 — replica kill/respawn mid-trace.  A Poisson trace replays
/// through two replicas; replica 0 panics partway in.  The router must
/// drain it (in-flight work surfaces as retryable 503-shaped rejects,
/// backlog re-routes), respawn it (`router_respawns`), and leave NO
/// request without a terminal outcome — the no-healthy-request-lost
/// invariant, now asserted trace-wide instead of per-hand-built-case.
#[test]
fn replica_kill_respawn_mid_trace_no_request_lost() {
    const N: usize = 80;
    let spec = TraceSpec::mixed(
        ArrivalProcess::Poisson { rate_per_s: 100.0 },
        128,
        8,
    );
    let trace = spec.generate(N, 31).unwrap();
    let mut router = two_replicas(|id| SimProfile {
        token_us: TOKEN_US,
        // Replica 0 dies after ~1/4 of the trace's ~640 tokens.
        panic_after_tokens: (id == 0).then_some(150),
        ..SimProfile::default()
    });
    let report = replay_fleet(
        &trace,
        &mut router,
        &ReplayOpts {
            time_scale: 0.002,
            deadline: Duration::from_secs(20),
        },
    );
    let c = router.counters();
    router.shutdown();
    assert_eq!(report.requests, N);
    assert_eq!(report.lost, 0, "a request vanished without a terminal event");
    let failed: usize = report.classes.iter().map(|cl| cl.failed).sum();
    let done: usize = report.classes.iter().map(|cl| cl.completed).sum();
    let rejected: usize = report.classes.iter().map(|cl| cl.rejected).sum();
    assert_eq!(failed, 0, "panic must not surface as HTTP-500 failures");
    assert_eq!(done + rejected, N);
    assert!(done > 0, "fleet stopped completing work after the kill");
    assert!(c.respawns >= 1, "dead replica was never respawned");
    assert_eq!(
        c.rejects_invalid, 0,
        "kill chaos must only produce retryable rejects"
    );
}

/// Chaos 4 — KV-pressure downshift under a sustained burst.  A bursty
/// trace's KV demand runs against the REAL byte-budgeted pool; each
/// admission prices its target through `downshift_for_pressure` on live
/// pool pressure — the exact policy behind the core's `admit_downshifts`
/// counter.  Under the burst the pool must cross the pressure threshold
/// and downshift (but never below the ladder floor), and every request
/// must still reach a terminal outcome (served or capacity-rejected).
#[test]
fn kv_pressure_downshift_under_sustained_burst() {
    use dp_llm::costmodel::{downshift_for_pressure, DOWNSHIFT_PRESSURE};
    use dp_llm::runtime::kvpool::KvPool;
    const N: usize = 300;
    let targets = [3.25, 3.5, 4.5, 5.5];
    let spec = TraceSpec::mixed(burst(), 64, 16);
    let trace = spec.generate(N, 47).unwrap();
    // Budget sized to ~6 concurrent worst-case sequences: the burst must
    // queue against it.
    let mut pool: KvPool<()> = KvPool::new(6 * 80, 1);
    let mut active: Vec<usize> = Vec::new(); // admitted tier sizes
    let (mut served, mut rejected, mut downshifts) = (0usize, 0usize, 0usize);
    let mut floor_respected = true;
    for (i, e) in trace.events.iter().enumerate() {
        // Sustained burst: only every third arrival frees a slot first.
        if i % 3 == 0 {
            if let Some(t) = active.pop() {
                pool.release(t, None);
            }
        }
        let tier = e.prompt_tokens + e.max_new;
        let pressure = pool.pressure();
        assert!((0.0..=1.0).contains(&pressure), "pressure {pressure}");
        let want = 5.5;
        let target = downshift_for_pressure(&targets, want, pressure);
        if target < want {
            downshifts += 1;
            floor_respected &= target >= targets[0];
            assert!(
                pressure >= DOWNSHIFT_PRESSURE,
                "downshift below the pressure threshold"
            );
        }
        match pool.charge(tier) {
            Ok(()) => {
                active.push(tier);
                served += 1;
            }
            Err(_) => rejected += 1, // capacity reject: terminal
        }
    }
    for t in active {
        pool.release(t, None);
    }
    assert_eq!(served + rejected, N, "every request reached a terminal state");
    assert!(served > 0 && rejected > 0, "burst never pressured the pool");
    assert!(
        downshifts > 0,
        "sustained burst never triggered a precision downshift"
    );
    assert!(floor_respected, "downshift went below the ladder floor");
    assert_eq!(pool.in_use_bytes(), 0, "byte accounting leaked");
}

/// Chaos 5 — flight-recorder event ordering across a kill/respawn.  A
/// three-replica fleet (replica 2 premium, the only fleet in this
/// binary with a replica id 2 — so its events are unambiguous even
/// though the global tracer is shared) takes a premium burst; replica 2
/// panics mid-burst.  The recorder must hold a `drain` event for
/// replica 2 strictly before its `respawn`, and the drained requests
/// must still reach terminal outcomes on the surviving replicas.
#[test]
fn kill_respawn_orders_drain_before_respawn_in_trace() {
    use dp_llm::coordinator::qos::QosBudget;
    use dp_llm::coordinator::sched::Request;
    use dp_llm::obs::{global_tracer, EventKind};

    global_tracer().set_enabled(true);
    let specs = vec![
        ReplicaSpec::sim(0, &["3.25"], false, TOKEN_US as f64 / 1e3),
        ReplicaSpec::sim(1, &["3.50"], false, TOKEN_US as f64 / 1e3),
        ReplicaSpec::sim(2, &["4.75"], true, TOKEN_US as f64 / 1e3),
    ];
    let mut router = Router::new(
        specs,
        Box::new(|spec| {
            sim_link(spec, SimProfile {
                token_us: 500,
                slots: 2,
                // Only the premium replica dies; the fault is
                // token-count-keyed so the respawned worker (whose
                // backlog re-routed away) never re-trips it.
                panic_after_tokens: (spec.id == 2).then_some(6),
                ..SimProfile::default()
            })
        }),
        RouterConfig {
            steal_threshold: usize::MAX, // isolate drain from stealing
            ..RouterConfig::default()
        },
    );
    const N: u64 = 6;
    for id in 0..N {
        let req = Request::new(id, "p", 2, QosBudget::tight(5.0));
        assert!(router.submit(req, None).is_none());
    }
    let events = drive(&mut router, N as usize, Duration::from_secs(20));
    assert!(router.counters().respawns >= 1, "replica 2 never respawned");
    assert!(events.iter().any(|e| matches!(
        e, RouterEvent::Respawned { replica: 2 })));
    router.shutdown();

    // The recorder's view of the same incident: drain strictly before
    // respawn for replica 2.  snapshot() is already timestamp-sorted
    // (stable, so same-thread ties keep program order).
    let snap = global_tracer().snapshot();
    let drain_at = snap.events.iter().position(|e| matches!(
        e.kind, EventKind::Drain { replica: 2, .. }));
    let respawn_at = snap.events.iter().position(|e| matches!(
        e.kind, EventKind::Respawn { replica: 2 }));
    let drain_at = drain_at.expect("no drain event traced for replica 2");
    let respawn_at = respawn_at.expect("no respawn event traced for replica 2");
    assert!(drain_at < respawn_at,
            "drain (idx {drain_at}) must precede respawn (idx {respawn_at})");
    // The burst also left request-lifecycle events on the precision
    // replica's route track.
    assert!(snap.events.iter().any(|e| matches!(
        e.kind, EventKind::Route { replica: 2, premium: true, .. })));
}
