//! Integration tests over the real artifacts (skipped when `make artifacts`
//! has not run yet).  These are the cross-language contract checks:
//! the Rust loader executing the AOT HLO must reproduce jax's numerics.

use std::sync::Arc;

use dp_llm::anyprec::GROUPS;
use dp_llm::evalharness::{build_session, perplexity, Method};
use dp_llm::model::{art, artifacts_available, Manifest, ModelAssets};
use dp_llm::runtime::decode::EstMode;
use dp_llm::runtime::Runtime;
use dp_llm::tokenizer::Tokenizer;
use dp_llm::util::npz::{load_npz, load_u16_bin};

const MODEL: &str = "dpl-tiny";

macro_rules! require_artifacts {
    () => {
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// The golden decode-step vectors produced by jax must be reproduced by the
/// PJRT execution of the HLO-text artifact — logits, KV, estimates, flags.
#[test]
fn golden_decode_roundtrip() {
    require_artifacts!();
    let manifest = Manifest::load().unwrap();
    let entry = manifest.entry(MODEL, "decode_step").unwrap();
    let rt = Runtime::new().unwrap();
    let exe = rt.load(&entry).unwrap();
    let golden = load_npz(&art(&["hlo", MODEL, "golden_decode.npz"])).unwrap();

    let mut literals = Vec::new();
    for name in &entry.args {
        let arr = golden
            .get(&format!("in_{name}"))
            .unwrap_or_else(|| panic!("golden missing in_{name}"));
        let dims: Vec<i64> = arr.shape.iter().map(|&d| d as i64).collect();
        let lit = match &arr.data {
            dp_llm::util::npz::NpyData::I32(v) => {
                xla::Literal::vec1(v).reshape(&dims).unwrap()
            }
            _ => {
                let v = arr.to_f32();
                xla::Literal::vec1(&v).reshape(&dims).unwrap()
            }
        };
        literals.push(lit);
    }
    let out = exe.run_literals(&literals).unwrap();

    for name in ["logits", "kv"] {
        let want = golden[&format!("out_{name}")].to_f32();
        let got = out.f32_vec(name).unwrap();
        assert_eq!(want.len(), got.len(), "{name} length");
        let d = max_abs_diff(&want, &got);
        assert!(d < 2e-3, "{name} max diff {d}");
    }
    for g in GROUPS {
        for prefix in ["est", "useh"] {
            let key = format!("{prefix}_{g}");
            let want = golden[&format!("out_{key}")].to_f32();
            let got = out.f32_vec(&key).unwrap();
            let d = max_abs_diff(&want, &got);
            assert!(d < 2e-3, "{key} max diff {d}");
        }
    }
}

/// Same contract for the prefill graph (static positions, full-prompt KV).
#[test]
fn golden_prefill_roundtrip() {
    require_artifacts!();
    let manifest = Manifest::load().unwrap();
    let entry = manifest.entry(MODEL, "prefill_64").unwrap();
    let rt = Runtime::new().unwrap();
    let exe = rt.load(&entry).unwrap();
    let golden = load_npz(&art(&["hlo", MODEL, "golden_prefill.npz"])).unwrap();
    let mut literals = Vec::new();
    for name in &entry.args {
        let arr = &golden[&format!("in_{name}")];
        let dims: Vec<i64> = arr.shape.iter().map(|&d| d as i64).collect();
        let lit = match &arr.data {
            dp_llm::util::npz::NpyData::I32(v) => {
                xla::Literal::vec1(v).reshape(&dims).unwrap()
            }
            _ => xla::Literal::vec1(&arr.to_f32()).reshape(&dims).unwrap(),
        };
        literals.push(lit);
    }
    let out = exe.run_literals(&literals).unwrap();
    for name in ["logits_last", "kv"] {
        let want = golden[&format!("out_{name}")].to_f32();
        let got = out.f32_vec(name).unwrap();
        let d = max_abs_diff(&want, &got);
        assert!(d < 2e-3, "{name} max diff {d}");
    }
}

/// The standalone Pallas bitplane-GEMV kernel (L1, via HLO) must agree with
/// the Rust-native dequantizer (L3 substrate) on the real quantized store.
#[test]
fn anyprec_kernel_matches_rust_dequant() {
    require_artifacts!();
    let manifest = Manifest::load().unwrap();
    let assets = ModelAssets::load(MODEL).unwrap();
    let store = assets.store.group("wq").unwrap();
    let rt = Runtime::new().unwrap();

    for bits in [3u8, 4, 5, 6] {
        let entry = manifest
            .entry(MODEL, &format!("anyprec_gemv_{bits}"))
            .unwrap();
        let exe = rt.load(&entry).unwrap();
        // layer 0 planes as [6, out, in/8] u8 literal + lut + x
        let (out_d, in_d) = (store.out_dim, store.in_dim);
        let bytes_in = in_d / 8;
        let layer_planes = &store.planes[..6 * out_d * bytes_in];
        let planes_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[6, out_d, bytes_in],
            layer_planes,
        )
        .unwrap();
        let lut = &store.luts[&bits][..out_d * (1 << bits)];
        let lut_lit = xla::Literal::vec1(lut)
            .reshape(&[out_d as i64, 1i64 << bits])
            .unwrap();
        let x: Vec<f32> = (0..in_d).map(|i| ((i * 37 % 17) as f32 - 8.0) * 0.1).collect();
        let x_lit = xla::Literal::vec1(&x);

        let out = exe.run_literals(&[planes_lit, lut_lit, x_lit]).unwrap();
        let got = out.f32_vec("y").unwrap();

        let w = store.dequant(0, bits).unwrap();
        let want = w.gemv(&x).unwrap();
        let d = max_abs_diff(&want, &got);
        assert!(d < 1e-3, "bits={bits} max diff {d}");
    }
}

/// Rust tokenizer parity with the Python encoder: re-encoding the decoded
/// prefix of a build-time-tokenized stream reproduces the exact ids.
#[test]
fn tokenizer_parity_with_python_stream() {
    require_artifacts!();
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap();
    let ids = load_u16_bin(&art(&["data", "synthwiki_eval.bin"])).unwrap();
    let n = ids.len().min(4000);
    let prefix: Vec<u32> = ids[..n].iter().map(|&i| i as u32).collect();
    let text = tok.decode(&prefix);
    let re: Vec<u32> = tok.encode(&text);
    // A trailing partial word may differ; everything before it must match.
    let check = re.len().min(prefix.len()).saturating_sub(8);
    assert!(check > 3000);
    assert_eq!(&re[..check], &prefix[..check]);
}

/// End-to-end decode through a DP-LLM configuration: finite logits, live
/// precision switching, effective bits within the candidate range.
#[test]
fn dpllm_session_decodes() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();

    let mut kv = session.zero_kv();
    let mut sel = session.selector_state();
    let mut tokv = 12u32;
    for t in 0..6 {
        let out = session
            .step(tokv, t, &kv, &sel.use_h_async, EstMode::Approx)
            .unwrap();
        assert_eq!(out.logits.len(), session.cfg.vocab);
        assert!(out.logits.iter().all(|v| v.is_finite()));
        for g in GROUPS {
            assert!(out.ests[g].iter().all(|v| v.is_finite() && *v >= 0.0));
        }
        sel.observe(&out.ests, &out.use_eff);
        kv = out.kv;
        tokv = dp_llm::runtime::decode::DecodeSession::argmax(&out.logits);
    }
    let eff = sel.effective_bits();
    assert!(eff >= 3.0 && eff <= 6.0, "effective bits {eff}");
}

/// Perplexity ordering sanity: 6-bit uniform must beat 3-bit uniform, and a
/// DP-LLM config at 4.0 must land between (or beat) them.
#[test]
fn ppl_ordering_uniform() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let stream = load_u16_bin(&art(&["data", "synthwiki_eval.bin"])).unwrap();

    let eval = |m: &Method| {
        let s = build_session(&rt, &assets, &manifest, 5, m).unwrap();
        perplexity(&s, &stream, 64, 256, EstMode::Approx).unwrap().ppl
    };
    let p3 = eval(&Method::Uniform { bits: 3 });
    let p6 = eval(&Method::Uniform { bits: 6 });
    assert!(p6 < p3, "uniform6 {p6} !< uniform3 {p3}");
    let pd = eval(&Method::Dpllm { tag: "4.00".into() });
    assert!(pd < p3 * 1.02, "dpllm@4 {pd} vs uniform3 {p3}");
    assert!(pd > p6 * 0.9, "dpllm@4 {pd} suspiciously below uniform6 {p6}");
}

/// Prefill + decode continuation through the serving path.
#[test]
fn prefill_then_decode() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Uniform { bits: 6 };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap();

    let prompt = tok.encode("The town of");
    let pre = session.prefill(&prompt).unwrap();
    assert_eq!(pre.logits.len(), session.cfg.vocab);
    let sel = session.selector_state();
    let next = dp_llm::runtime::decode::DecodeSession::argmax(&pre.logits);
    let out = session
        .step(next, prompt.len(), &pre.kv, &sel.use_h_async, EstMode::Approx)
        .unwrap();
    assert!(out.logits.iter().all(|v| v.is_finite()));
}
