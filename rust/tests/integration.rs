//! Integration tests over the real artifacts.  These are the
//! cross-language contract checks: the Rust loader executing the AOT HLO
//! must reproduce jax's numerics.
//!
//! Hermeticity: `cargo test -q` on a fresh checkout must pass with no
//! artifacts and no device, so every test here is gated on the
//! `DPLLM_ARTIFACTS` environment variable (pointing at a `make artifacts`
//! output tree) AND the manifest actually existing.  Unset → skip.

use std::cell::RefCell;
use std::rc::Rc;
use std::sync::Arc;

use dp_llm::anyprec::GROUPS;
use dp_llm::coordinator::qos::{QosBudget, UtilizationSim};
use dp_llm::coordinator::sched::{Request, RequestQueue, SchedPolicy};
use dp_llm::coordinator::service::{CoreConfig, CoreEvent, ServingCore,
                                   ServingEngine};
use dp_llm::evalharness::{build_session, build_session_with_cache, perplexity,
                          perplexity_batched, tasks, Method};
use dp_llm::model::{art, artifacts_available, Manifest, ModelAssets};
use dp_llm::runtime::decode::{DecodeSession, EstMode};
use dp_llm::runtime::kvpool::{KvPool, SharedKvPool};
use dp_llm::runtime::spec::{spec_round, GammaController, SpecState};
use dp_llm::runtime::Runtime;
use dp_llm::tokenizer::Tokenizer;
use dp_llm::util::npz::{load_npz, load_u16_bin};

const MODEL: &str = "dpl-tiny";

macro_rules! require_artifacts {
    () => {
        if std::env::var("DPLLM_ARTIFACTS").is_err() {
            eprintln!(
                "skipping: set DPLLM_ARTIFACTS=<artifacts dir> to run \
                 artifact-backed integration tests"
            );
            return;
        }
        if !artifacts_available() {
            eprintln!("skipping: artifacts not built (run `make artifacts`)");
            return;
        }
    };
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max)
}

/// The golden decode-step vectors produced by jax must be reproduced by the
/// PJRT execution of the HLO-text artifact — logits, KV, estimates, flags.
#[test]
fn golden_decode_roundtrip() {
    require_artifacts!();
    let manifest = Manifest::load().unwrap();
    let entry = manifest.entry(MODEL, "decode_step").unwrap();
    let rt = Runtime::new().unwrap();
    let exe = rt.load(&entry).unwrap();
    let golden = load_npz(&art(&["hlo", MODEL, "golden_decode.npz"])).unwrap();

    let mut literals = Vec::new();
    for name in &entry.args {
        let arr = golden
            .get(&format!("in_{name}"))
            .unwrap_or_else(|| panic!("golden missing in_{name}"));
        let dims: Vec<i64> = arr.shape.iter().map(|&d| d as i64).collect();
        let lit = match &arr.data {
            dp_llm::util::npz::NpyData::I32(v) => {
                xla::Literal::vec1(v).reshape(&dims).unwrap()
            }
            _ => {
                let v = arr.to_f32();
                xla::Literal::vec1(&v).reshape(&dims).unwrap()
            }
        };
        literals.push(lit);
    }
    let out = exe.run_literals(&literals).unwrap();

    for name in ["logits", "kv"] {
        let want = golden[&format!("out_{name}")].to_f32();
        let got = out.f32_vec(name).unwrap();
        assert_eq!(want.len(), got.len(), "{name} length");
        let d = max_abs_diff(&want, &got);
        assert!(d < 2e-3, "{name} max diff {d}");
    }
    for g in GROUPS {
        for prefix in ["est", "useh"] {
            let key = format!("{prefix}_{g}");
            let want = golden[&format!("out_{key}")].to_f32();
            let got = out.f32_vec(&key).unwrap();
            let d = max_abs_diff(&want, &got);
            assert!(d < 2e-3, "{key} max diff {d}");
        }
    }
}

/// Same contract for the prefill graph (static positions, full-prompt KV).
#[test]
fn golden_prefill_roundtrip() {
    require_artifacts!();
    let manifest = Manifest::load().unwrap();
    let entry = manifest.entry(MODEL, "prefill_64").unwrap();
    let rt = Runtime::new().unwrap();
    let exe = rt.load(&entry).unwrap();
    let golden = load_npz(&art(&["hlo", MODEL, "golden_prefill.npz"])).unwrap();
    let mut literals = Vec::new();
    for name in &entry.args {
        let arr = &golden[&format!("in_{name}")];
        let dims: Vec<i64> = arr.shape.iter().map(|&d| d as i64).collect();
        let lit = match &arr.data {
            dp_llm::util::npz::NpyData::I32(v) => {
                xla::Literal::vec1(v).reshape(&dims).unwrap()
            }
            _ => xla::Literal::vec1(&arr.to_f32()).reshape(&dims).unwrap(),
        };
        literals.push(lit);
    }
    let out = exe.run_literals(&literals).unwrap();
    for name in ["logits_last", "kv"] {
        let want = golden[&format!("out_{name}")].to_f32();
        let got = out.f32_vec(name).unwrap();
        let d = max_abs_diff(&want, &got);
        assert!(d < 2e-3, "{name} max diff {d}");
    }
}

/// The standalone Pallas bitplane-GEMV kernel (L1, via HLO) must agree with
/// the Rust-native dequantizer (L3 substrate) on the real quantized store.
#[test]
fn anyprec_kernel_matches_rust_dequant() {
    require_artifacts!();
    let manifest = Manifest::load().unwrap();
    let assets = ModelAssets::load(MODEL).unwrap();
    let store = assets.store.group("wq").unwrap();
    let rt = Runtime::new().unwrap();

    for bits in [3u8, 4, 5, 6] {
        let entry = manifest
            .entry(MODEL, &format!("anyprec_gemv_{bits}"))
            .unwrap();
        let exe = rt.load(&entry).unwrap();
        // layer 0 planes as [6, out, in/8] u8 literal + lut + x
        // (the store is plane-major; reassemble this layer's plane stack)
        let (out_d, in_d) = (store.out_dim, store.in_dim);
        let bytes_in = in_d / 8;
        let mut layer_planes = Vec::with_capacity(6 * out_d * bytes_in);
        for p in 0..6 {
            layer_planes.extend_from_slice(store.plane_layer(p, 0).unwrap());
        }
        let planes_lit = xla::Literal::create_from_shape_and_untyped_data(
            xla::ElementType::U8,
            &[6, out_d, bytes_in],
            &layer_planes,
        )
        .unwrap();
        let lut = &store.lut(bits).unwrap()[..out_d * (1 << bits)];
        let lut_lit = xla::Literal::vec1(lut)
            .reshape(&[out_d as i64, 1i64 << bits])
            .unwrap();
        let x: Vec<f32> = (0..in_d).map(|i| ((i * 37 % 17) as f32 - 8.0) * 0.1).collect();
        let x_lit = xla::Literal::vec1(&x);

        let out = exe.run_literals(&[planes_lit, lut_lit, x_lit]).unwrap();
        let got = out.f32_vec("y").unwrap();

        let w = store.dequant(0, bits).unwrap();
        let want = w.gemv(&x).unwrap();
        let d = max_abs_diff(&want, &got);
        assert!(d < 1e-3, "bits={bits} max diff {d}");
    }
}

/// Rust tokenizer parity with the Python encoder: re-encoding the decoded
/// prefix of a build-time-tokenized stream reproduces the exact ids.
#[test]
fn tokenizer_parity_with_python_stream() {
    require_artifacts!();
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap();
    let ids = load_u16_bin(&art(&["data", "synthwiki_eval.bin"])).unwrap();
    let n = ids.len().min(4000);
    let prefix: Vec<u32> = ids[..n].iter().map(|&i| i as u32).collect();
    let text = tok.decode(&prefix);
    let re: Vec<u32> = tok.encode(&text);
    // A trailing partial word may differ; everything before it must match.
    let check = re.len().min(prefix.len()).saturating_sub(8);
    assert!(check > 3000);
    assert_eq!(&re[..check], &prefix[..check]);
}

/// End-to-end decode through a DP-LLM configuration on the GenState path:
/// finite logits, live precision switching, effective bits in range.
#[test]
fn dpllm_session_decodes() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();

    let mut gen = session.begin_empty().unwrap();
    let mut tokv = 12u32;
    for t in 0..6 {
        let out = session.advance(&mut gen, tokv, EstMode::Approx).unwrap();
        assert_eq!(out.logits.len(), session.cfg.vocab);
        assert!(out.logits.iter().all(|v| v.is_finite()));
        for g in GROUPS {
            assert!(out.ests[g].iter().all(|v| v.is_finite() && *v >= 0.0));
        }
        assert_eq!(gen.pos, t + 1);
        tokv = DecodeSession::argmax(&out.logits).unwrap();
    }
    let eff = gen.sel.effective_bits();
    assert!(eff >= 3.0 && eff <= 6.0, "effective bits {eff}");
}

/// GenState device residency: after warm-up, a decode step's host→device
/// traffic must be O(1) in KV size — the KV cache (the only large per-step
/// tensor) stays on the device between steps.
#[test]
fn gen_state_step_traffic_o1_in_kv() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();

    let mut gen = session.begin_empty().unwrap();
    assert!(gen.kv_on_device(), "KV must start device-resident");
    // Warm-up: populates rope/scalar caches for positions 0..2.
    session.advance(&mut gen, 1, EstMode::Approx).unwrap();
    session.advance(&mut gen, 2, EstMode::Approx).unwrap();
    if !gen.kv_on_device() {
        eprintln!("skipping: graph is tuple-lowered; host fallback in effect");
        return;
    }
    // A step at a *fresh* position uploads at most: rope tables (head_dim
    // floats), possibly a new token/pos scalar, and changed flag vectors —
    // all O(1) in kv_bytes.
    let before = rt.transfers().snapshot();
    session.advance(&mut gen, 3, EstMode::Approx).unwrap();
    let after = rt.transfers().snapshot();
    let step_bytes = after.upload_bytes_since(&before);
    let kv_bytes = session.kv_bytes() as u64;
    assert!(
        step_bytes < kv_bytes / 4,
        "step uploaded {step_bytes}B — not O(1) vs kv {kv_bytes}B"
    );
}

/// GenState buffer reuse: a second generation revisiting the same
/// positions must hit the rope device cache (no re-upload of rope tables,
/// and certainly no re-upload of weights).
#[test]
fn gen_state_reuses_rope_buffers_across_generations() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();

    let mut g1 = session.begin_empty().unwrap();
    for t in 0..4 {
        session.advance(&mut g1, t + 1, EstMode::Approx).unwrap();
    }
    let (hits_before, misses_before) = session.rope_cache_stats();
    assert_eq!(misses_before, 4, "first pass populates the cache");

    // Second generation, same positions: all rope lookups must be hits.
    let mut g2 = session.begin_empty().unwrap();
    for t in 0..4 {
        session.advance(&mut g2, t + 1, EstMode::Approx).unwrap();
    }
    let (hits_after, misses_after) = session.rope_cache_stats();
    assert_eq!(misses_after, misses_before, "repeated positions re-uploaded rope");
    assert_eq!(hits_after, hits_before + 4);
}

/// ServingCore interleaves two concurrent generations at token
/// granularity under FIFO: within any 2-token window both requests
/// advance.
#[test]
fn serving_core_interleaves_two_requests_fifo() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    let mut queue = RequestQueue::new(SchedPolicy::Fifo);
    queue.push(Request::new(1, "The town of", 6, QosBudget::best_effort()));
    queue.push(Request::new(2, "The town of", 6, QosBudget::best_effort()));
    let mut util = UtilizationSim::constant(0.0);
    let mut token_owners: Vec<u64> = Vec::new();
    let outcomes = ServingCore::new(&engine, SchedPolicy::Fifo)
        .run(&mut queue, &mut util, &mut |ev| {
            // index 0 is the prefill-produced token, emitted alongside the
            // first decoded token; count decode steps only.
            if let CoreEvent::Token { id, index, .. } = ev {
                if *index > 0 {
                    token_owners.push(*id);
                }
            }
        })
        .unwrap();
    assert_eq!(outcomes.len(), 2);
    // Both requests run to completion and, while BOTH are decodable,
    // strictly alternate: each advances within any 2-token window.
    // (Prompt ingestion is scheduled one chunk per round now, so request
    // 2's first decode token lands one round after request 1's — the
    // interleaving window is between 2's first and 1's last token.)
    assert_eq!(token_owners.len(), 10, "5 decode steps per request");
    let first_2 = token_owners.iter().position(|&id| id == 2).unwrap();
    let last_1 = token_owners.iter().rposition(|&id| id == 1).unwrap();
    assert!(last_1 > first_2, "requests never overlapped: {token_owners:?}");
    for w in token_owners[first_2..=last_1].windows(2) {
        assert_ne!(w[0], w[1], "token stream not interleaved: {token_owners:?}");
    }
}

/// Batched decode parity: two slots advanced through `advance_batch` must
/// reproduce the single-step `advance` numerics token for token — the
/// fast path is a drop-in replacement, not an approximation (mirrors the
/// jax-level test_batched_decode_matches_per_slot_single_step).
#[test]
fn advance_batch_matches_single_step_numerics() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    if session.max_batch() < 2 {
        eprintln!("skipping: artifacts predate the batched decode entries");
        return;
    }
    let mut g_ref = session.begin_empty().unwrap();
    let mut g_a = session.begin_empty().unwrap();
    let mut g_b = session.begin_empty().unwrap();
    let before = rt.transfers().snapshot();
    for &t in &[5u32, 9, 2, 14] {
        let out_ref = session.advance(&mut g_ref, t, EstMode::Approx).unwrap();
        let outs = {
            let mut slots = [(&mut g_a, t), (&mut g_b, t)];
            session.advance_batch(&mut slots, EstMode::Approx).unwrap()
        };
        assert_eq!(outs.len(), 2);
        for out in &outs {
            assert_eq!(out.logits.len(), out_ref.logits.len());
            let d = max_abs_diff(&out.logits, &out_ref.logits);
            assert!(d < 2e-3, "batched vs single logits diff {d}");
            for g in GROUPS {
                let de = max_abs_diff(&out.ests[g], &out_ref.ests[g]);
                assert!(de < 2e-3, "est_{g} diff {de}");
                assert_eq!(out.use_eff[g], out_ref.use_eff[g], "useh_{g}");
            }
        }
    }
    let after = rt.transfers().snapshot();
    assert_eq!(after.batched_steps - before.batched_steps, 4);
    assert_eq!(after.batch_occupancy - before.batch_occupancy, 8);
    assert_eq!(g_a.pos, 4);
    assert!(g_a.kv_on_device() && g_b.kv_on_device());
    let (er, ea) = (g_ref.sel.effective_bits(), g_a.sel.effective_bits());
    assert!((er - ea).abs() < 1e-9, "effective bits diverged: {er} vs {ea}");
}

/// The serving core's batched fast path engages for concurrent
/// same-target requests: batched_steps > 0 with mean occupancy ≥ 2,
/// asserted through the Runtime::transfers counter pair.
#[test]
fn serving_core_batches_and_counts_occupancy() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    if engine.session_for_target(4.0).max_batch() < 2 {
        eprintln!("skipping: artifacts predate the batched decode entries");
        return;
    }
    let mut core = ServingCore::new(&engine, SchedPolicy::Fifo);
    for id in [1u64, 2] {
        core.admit_pinned(
            Request::new(id, "The town of", 6, QosBudget::best_effort()), 4.0)
            .unwrap();
    }
    let before = rt.transfers().snapshot();
    let outcomes = core.drain(&mut |_| {}).unwrap();
    let after = rt.transfers().snapshot();
    assert_eq!(outcomes.len(), 2);
    let steps = after.batched_steps - before.batched_steps;
    let occ = after.batch_occupancy - before.batch_occupancy;
    assert!(steps > 0, "batched fast path never engaged");
    assert!(occ >= 2 * steps, "mean occupancy below 2: {occ} slots / {steps} steps");
}

/// Acceptance bar (ISSUE 3): with 4 concurrent same-target requests the
/// device dispatch count per generated token must be ≤ 0.35 (vs 1.0 for
/// per-request dispatch), derived from the batched_steps/batch_occupancy
/// counters plus the streamed token count.
#[test]
fn dispatch_calls_per_token_bounded_with_four_concurrent() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    if engine.session_for_target(4.0).max_batch() < 4 {
        eprintln!("skipping: artifacts lack the B=4 batched decode entry");
        return;
    }
    let mut core = ServingCore::new(&engine, SchedPolicy::Fifo);
    for id in 0..4u64 {
        core.admit_pinned(
            Request::new(id, "The town of", 9, QosBudget::best_effort()), 4.0)
            .unwrap();
    }
    let before = rt.transfers().snapshot();
    let mut decoded = 0u64;
    let outcomes = core
        .drain(&mut |ev| {
            if let CoreEvent::Token { index, .. } = ev {
                if *index > 0 {
                    decoded += 1;
                }
            }
        })
        .unwrap();
    let after = rt.transfers().snapshot();
    assert_eq!(outcomes.len(), 4);
    assert!(decoded > 0);
    let batched = after.batched_steps - before.batched_steps;
    let occupancy = after.batch_occupancy - before.batch_occupancy;
    // Tokens not decoded through a batched dispatch each paid one
    // per-request dispatch.  (saturating: a slot whose token never
    // streamed — argmax failure — still counted occupancy.)
    let singles = decoded.saturating_sub(occupancy);
    let per_token = (batched + singles) as f64 / decoded as f64;
    assert!(
        per_token <= 0.35,
        "dispatch calls per token {per_token:.3} (batched {batched}, \
         occupancy {occupancy}, singles {singles}, tokens {decoded})"
    );
}

/// Regression (ISSUE 3 bugfix): when a request finishes mid-batch, the
/// freed slot is refilled from the queue immediately — the replacement's
/// tokens interleave with the still-running batch mate instead of waiting
/// for the whole batch to drain.
#[test]
fn admission_refills_freed_batch_slot_mid_flight() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    if engine.session_for_target(4.0).max_batch() < 2 {
        eprintln!("skipping: artifacts predate the batched decode entries");
        return;
    }
    let mut queue = RequestQueue::new(SchedPolicy::Fifo);
    queue.push(Request::new(1, "The town of", 8, QosBudget::best_effort()));
    queue.push(Request::new(2, "The town of", 3, QosBudget::best_effort()));
    queue.push(Request::new(3, "The town of", 8, QosBudget::best_effort()));
    let mut util = UtilizationSim::constant(0.0);
    // (id, is_done) in emission order.
    let mut log: Vec<(u64, bool)> = Vec::new();
    let outcomes = ServingCore::new(&engine, SchedPolicy::Fifo)
        .with_max_active(2)
        .run(&mut queue, &mut util, &mut |ev| match ev {
            CoreEvent::Token { id, .. } => log.push((*id, false)),
            CoreEvent::Done(o) => log.push((o.id, true)),
            CoreEvent::Failed { .. } | CoreEvent::Error { .. } => {}
        })
        .unwrap();
    assert_eq!(outcomes.len(), 3);
    let pos = |id, done: bool| {
        log.iter()
            .position(|&e| e == (id, done))
            .unwrap_or_else(|| panic!("missing event ({id}, {done}): {log:?}"))
    };
    let first_tok3 = pos(3, false);
    // Capacity 2: request 3 must wait for a free slot...
    assert!(pos(2, true) < first_tok3, "request 3 served before capacity freed");
    // ...but the regression bar: it starts streaming while request 1 is
    // still mid-generation (admitted into the in-flight batch), not after
    // the original batch fully drained.
    assert!(first_tok3 < pos(1, true),
            "request 3 idled until the original batch drained: {log:?}");
}

/// A precision rebind that changes k of L layers must re-upload O(k) — not
/// O(L·groups) — weight bytes: unchanged layers come out of the weight
/// materialization cache and the stacks re-assemble device-side
/// (DESIGN.md §Perf, delta-rebind protocol).
#[test]
fn swap_bits_delta_materialization_uploads_o_k() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    // A *retaining* cache (the serving-engine configuration) — sessions
    // built with plain build_session use a zero-retention cache and
    // re-materialize whole stacks on rebind by design.
    let mut session = build_session_with_cache(
        &rt, &assets, &manifest, 5, &m, DecodeSession::fresh_weight_cache())
        .unwrap();

    // Flip the low candidate of wq in the first (up to) two layers.
    let mut ec = session.ec.clone();
    let flips: Vec<usize> = (0..session.cfg.n_layers.min(2))
        .map(|layer| layer * GROUPS.len()) // linear index of (layer, "wq")
        .collect();
    let k = flips.len();
    for &li in &flips {
        ec.wl_bits[li] = if ec.wl_bits[li] < 6 { ec.wl_bits[li] + 1 } else { 3 };
    }
    let layer_bytes = assets.store.group("wq").unwrap().layer_slab_bytes() as u64;

    let before = rt.transfers().snapshot();
    let mat_before = session.materialize_stats();
    let report = session.swap_bits(ec).unwrap();
    let after = rt.transfers().snapshot();
    let mat_after = session.materialize_stats();

    assert_eq!(report.layers_changed, k);
    assert_eq!(report.stacks_rebuilt, 1, "only wl_wq may rebuild");
    assert_eq!(report.selector_uploads, 0, "selector params were unchanged");
    // At most the k changed layers dequantize afresh (the cache may even
    // hold their new bitwidths already, from wh/prefill materialization).
    assert!(
        mat_after.misses - mat_before.misses <= k as u64,
        "rebind re-dequantized more than the changed layers: {mat_before:?} -> {mat_after:?}"
    );
    let uploaded = after.upload_bytes_since(&before);
    if after.assemblies > before.assemblies {
        // Device-side assembly: only changed layers crossed the bus.
        assert!(
            uploaded <= k as u64 * layer_bytes,
            "rebind uploaded {uploaded}B for k={k} layers of {layer_bytes}B"
        );
    } else {
        // Host-fallback assembly: one full wq stack — still one group, far
        // from the 21-stack full rebuild the seed paid.
        let l = session.cfg.n_layers as u64;
        assert!(
            uploaded <= (l + k as u64) * layer_bytes,
            "host-fallback rebind uploaded {uploaded}B"
        );
    }

    // The swapped session must still decode.
    let mut gen = session.begin_empty().unwrap();
    let out = session.advance(&mut gen, 7, EstMode::Approx).unwrap();
    assert!(out.logits.iter().all(|v| v.is_finite()));
}

/// Sessions built through one shared weight cache dedupe materialization:
/// an identical second configuration re-dequantizes nothing.
#[test]
fn shared_cache_dedupes_across_configs() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let cache = DecodeSession::fresh_weight_cache();
    let m = Method::Uniform { bits: 4 };
    let s1 = build_session_with_cache(&rt, &assets, &manifest, 5, &m,
                                      cache.clone()).unwrap();
    let snap1 = s1.materialize_stats();
    assert!(snap1.misses > 0);
    let s2 = build_session_with_cache(&rt, &assets, &manifest, 5, &m,
                                      cache.clone()).unwrap();
    let snap2 = s2.materialize_stats();
    assert_eq!(snap2.misses, snap1.misses,
               "identical config re-dequantized through the shared cache");
    assert!(snap2.hits > snap1.hits);
}

/// perplexity_batched reproduces perplexity's numerics through the
/// batched fast path (same chunking, same per-chunk GenStates) while
/// actually engaging batched dispatches.
#[test]
fn perplexity_batched_matches_single_path() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    if session.max_batch() < 2 {
        eprintln!("skipping: artifacts predate the batched decode entries");
        return;
    }
    let stream = load_u16_bin(&art(&["data", "synthwiki_eval.bin"])).unwrap();
    let single = perplexity(&session, &stream, 32, 128, EstMode::Approx).unwrap();
    let before = rt.transfers().snapshot();
    let batched =
        perplexity_batched(&session, &stream, 32, 128, EstMode::Approx, 4)
            .unwrap();
    let after = rt.transfers().snapshot();
    assert!(after.batched_steps > before.batched_steps,
            "batched perplexity never used a batched dispatch");
    assert_eq!(batched.tokens, single.tokens);
    // Logits agree to ~2e-3 between the vmapped and single graphs, so the
    // aggregate perplexities must track within a fraction of a percent.
    let rel = (batched.ppl - single.ppl).abs() / single.ppl;
    assert!(rel < 1e-2, "ppl diverged: {} vs {} (rel {rel})",
            batched.ppl, single.ppl);
    let deff = (batched.effective_bits - single.effective_bits).abs();
    assert!(deff < 0.05, "effective bits diverged by {deff}");
}

/// Perplexity ordering sanity: 6-bit uniform must beat 3-bit uniform, and a
/// DP-LLM config at 4.0 must land between (or beat) them.
#[test]
fn ppl_ordering_uniform() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let stream = load_u16_bin(&art(&["data", "synthwiki_eval.bin"])).unwrap();

    let eval = |m: &Method| {
        let s = build_session(&rt, &assets, &manifest, 5, m).unwrap();
        perplexity(&s, &stream, 64, 256, EstMode::Approx).unwrap().ppl
    };
    let p3 = eval(&Method::Uniform { bits: 3 });
    let p6 = eval(&Method::Uniform { bits: 6 });
    assert!(p6 < p3, "uniform6 {p6} !< uniform3 {p3}");
    let pd = eval(&Method::Dpllm { tag: "4.00".into() });
    assert!(pd < p3 * 1.02, "dpllm@4 {pd} vs uniform3 {p3}");
    assert!(pd > p6 * 0.9, "dpllm@4 {pd} suspiciously below uniform6 {p6}");
}

/// Speculative rounds over an identical (draft, target) pair — same
/// configuration, two sessions — must (a) accept every draft (the pair
/// shares numerics), (b) emit token-for-token the plain greedy sequence,
/// (c) keep the selector's effective-bit accounting in lockstep with
/// sequential decode, and (d) need ≤ 0.6 verify dispatches per generated
/// token (here exactly 1/(γ+1) = 0.2) — the ISSUE 4 acceptance bar, made
/// deterministic by removing draft/target disagreement.
#[test]
fn spec_round_identical_pair_parity_and_dispatch_bound() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let target = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    if target.spec_gammas().is_empty() {
        eprintln!("skipping: artifacts predate the verify_step_g* entries");
        return;
    }
    let gamma = *target.spec_gammas().last().unwrap();
    let draft = build_session(&rt, &assets, &manifest, 5, &m).unwrap();

    let mut state = SpecState {
        draft: &draft,
        draft_gen: draft.begin_empty().unwrap(),
        ctrl: GammaController::new(1.0, 2.0),
    };
    let mut tgen = target.begin_empty().unwrap();
    let mut rgen = target.begin_empty().unwrap(); // plain-decode reference

    // committed[p] = token fed at position p.
    let mut committed: Vec<u32> = vec![7];
    let mut ref_token = 7u32;
    let before = rt.transfers().snapshot();
    let rounds = 4usize;
    let mut emitted_total = 0usize;
    for _ in 0..rounds {
        let next = *committed.last().unwrap();
        let catchup: Vec<u32> =
            committed[state.draft_gen.pos..committed.len() - 1].to_vec();
        let round = spec_round(&mut state, &target, &mut tgen, next, &catchup,
                               gamma, EstMode::Approx)
            .unwrap();
        // Guaranteed progress: every round commits at least one token.
        assert!(!round.tokens.is_empty());
        assert_eq!(round.gamma, gamma);
        // Identical pair → every draft accepted, γ+1 tokens per round.
        assert_eq!(round.accepted_drafts, gamma,
                   "identical draft/target disagreed");
        assert_eq!(round.tokens.len(), gamma + 1);
        // Token-for-token parity with plain greedy decode.
        for &t in &round.tokens {
            let out = target.advance(&mut rgen, ref_token, EstMode::Approx)
                .unwrap();
            let want = DecodeSession::argmax(&out.logits).unwrap();
            assert_eq!(t, want, "speculative token diverged from plain greedy");
            ref_token = t;
            committed.push(t);
        }
        emitted_total += round.tokens.len();
        assert_eq!(tgen.pos, rgen.pos, "position counters diverged");
    }
    let after = rt.transfers().snapshot();
    let dispatches = after.spec_verify_dispatches - before.spec_verify_dispatches;
    assert_eq!(dispatches, rounds as u64);
    let per_token = dispatches as f64 / emitted_total as f64;
    assert!(per_token <= 0.6,
            "{per_token:.3} verify dispatches/token (bar: 0.6)");
    // Counters: all drafts counted, all accepted.
    assert_eq!(after.spec_drafted - before.spec_drafted,
               (rounds * gamma) as u64);
    assert_eq!(after.spec_accepted - before.spec_accepted,
               (rounds * gamma) as u64);
    // Selector accounting observed the same positions as plain decode.
    let (es, er) = (tgen.sel.effective_bits(), rgen.sel.effective_bits());
    assert!((es - er).abs() < 0.05, "effective bits diverged: {es} vs {er}");
}

/// γ = 0 must reproduce today's path exactly: a core with speculation
/// capped at γ = 0 and a core with speculation disabled produce the
/// identical token stream (and neither touches the verify counters).
#[test]
fn spec_gamma0_reproduces_plain_path() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["3.25", "4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    let run = |config: CoreConfig, id: u64| -> (String, u64) {
        let mut core = ServingCore::new(&engine, SchedPolicy::Fifo)
            .with_config(config);
        core.admit_pinned(
            Request::new(id, "The town of", 10, QosBudget::best_effort()), 4.0)
            .unwrap();
        let before = rt.transfers().snapshot();
        let outcomes = core.drain(&mut |_| {}).unwrap();
        let after = rt.transfers().snapshot();
        (outcomes.into_iter().next().unwrap().text,
         after.spec_verify_dispatches - before.spec_verify_dispatches)
    };
    let (text_off, v_off) =
        run(CoreConfig { spec: false, ..CoreConfig::default() }, 1);
    let (text_g0, v_g0) =
        run(CoreConfig { gamma_cap: 0, ..CoreConfig::default() }, 2);
    assert_eq!(v_off, 0, "spec-disabled run paid a verify dispatch");
    assert_eq!(v_g0, 0, "γ = 0 run paid a verify dispatch");
    assert_eq!(text_off, text_g0, "γ = 0 diverged from the plain path");
}

/// ISSUE 4 acceptance: a best-effort request through the serving core
/// rides the spec path (counters prove engagement), the verify-dispatch
/// budget holds at measured acceptance ≥ 0.5, and — because acceptance
/// compares against the target's own argmax — the streamed text is
/// byte-identical to a speculation-disabled run.
#[test]
fn spec_serving_core_engages_and_matches_plain_greedy() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["3.25", "4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    if engine.session_for_target(4.0).spec_gammas().is_empty() {
        eprintln!("skipping: artifacts predate the verify_step_g* entries");
        return;
    }
    let run = |config: CoreConfig, id: u64| -> (String, Vec<usize>, u64) {
        let mut core = ServingCore::new(&engine, SchedPolicy::Fifo)
            .with_config(config);
        core.admit_pinned(
            Request::new(id, "The town of", 24, QosBudget::best_effort()), 4.0)
            .unwrap();
        let mut decoded = 0u64;
        let mut indices = Vec::new();
        let outcomes = core
            .drain(&mut |ev| {
                if let CoreEvent::Token { index, .. } = ev {
                    indices.push(*index);
                    if *index > 0 {
                        decoded += 1;
                    }
                }
            })
            .unwrap();
        assert_eq!(core.spec_errors(), 0, "speculative rounds failed");
        (outcomes.into_iter().next().unwrap().text, indices, decoded)
    };

    let before = rt.transfers().snapshot();
    let (spec_text, indices, decoded) = run(CoreConfig::default(), 1);
    let after = rt.transfers().snapshot();
    let verify = after.spec_verify_dispatches - before.spec_verify_dispatches;
    let drafted = after.spec_drafted - before.spec_drafted;
    let accepted = after.spec_accepted - before.spec_accepted;
    assert!(verify > 0, "spec path never engaged for a best-effort request");
    assert!(drafted > 0);
    // Accepted runs stream in order: indices strictly increase by one.
    for w in indices.windows(2) {
        assert_eq!(w[1], w[0] + 1, "token stream out of order: {indices:?}");
    }
    let acceptance = accepted as f64 / drafted as f64;
    if acceptance >= 0.5 {
        let per_token = verify as f64 / decoded.max(1) as f64;
        assert!(per_token <= 0.6,
                "{per_token:.3} verify dispatches/token at acceptance \
                 {acceptance:.2} (bar: 0.6)");
    } else {
        eprintln!("note: measured acceptance {acceptance:.2} < 0.5; \
                   dispatch bound not asserted");
    }

    // Greedy parity end to end: speculation changes latency, not output.
    let (plain_text, _, _) =
        run(CoreConfig { spec: false, ..CoreConfig::default() }, 2);
    assert_eq!(spec_text, plain_text,
               "speculative decode changed the greedy output");
}

/// Grow a prompt until it tokenizes to at least `min_tokens` ids.
fn long_prompt(tok: &Tokenizer, min_tokens: usize) -> String {
    let mut s = String::new();
    let mut i = 0usize;
    while tok.encode(&s).len() < min_tokens {
        s.push_str(&format!("item {} of the ledger; ", i * 37 % 911));
        i += 1;
    }
    s
}

/// Chunked-prefill parity (the Rust half of the jax chain test): a chain
/// of `prefill_advance` chunks must reproduce the bucketed `begin` —
/// final logits AND subsequent greedy decode, token for token — so
/// chunk-scheduled ingestion is numerically invisible downstream.
#[test]
fn chunked_prefill_matches_bucketed_begin() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    if session.prefill_chunk_buckets().is_empty() {
        eprintln!("skipping: artifacts predate the prefill_chunk entries");
        return;
    }
    let prompt: Vec<u32> = (0..192u32).map(|i| (i * 17 + 3) % 1000).collect();
    let (mut g_ref, logits_ref) = session.begin(&prompt).unwrap();
    let before = rt.transfers().snapshot();
    let mut g_chunk = session.begin_chunked().unwrap();
    let n_chunks = (prompt.len() + 63) / 64;
    let mut logits_chunk = None;
    for (i, piece) in prompt.chunks(64).enumerate() {
        // Intermediate chunks skip the logits download (None returned).
        let got = session
            .prefill_advance(&mut g_chunk, piece, i + 1 == n_chunks)
            .unwrap();
        assert_eq!(got.is_some(), i + 1 == n_chunks);
        logits_chunk = got;
    }
    let logits_chunk = logits_chunk.expect("final chunk logits");
    let after = rt.transfers().snapshot();
    assert_eq!(after.prefill_chunks - before.prefill_chunks, 3);
    assert_eq!(g_chunk.pos, prompt.len());
    assert_eq!(logits_chunk.len(), logits_ref.len());
    let d = max_abs_diff(&logits_chunk, &logits_ref);
    assert!(d < 2e-3, "chunked vs bucketed prefill logits diff {d}");
    // Downstream parity: greedy decode stays in lockstep.
    let mut t_ref = DecodeSession::argmax(&logits_ref).unwrap();
    let mut t_chunk = DecodeSession::argmax(&logits_chunk).unwrap();
    assert_eq!(t_ref, t_chunk);
    for _ in 0..4 {
        let o_ref = session.advance(&mut g_ref, t_ref, EstMode::Approx).unwrap();
        let o_chunk = session
            .advance(&mut g_chunk, t_chunk, EstMode::Approx)
            .unwrap();
        t_ref = DecodeSession::argmax(&o_ref.logits).unwrap();
        t_chunk = DecodeSession::argmax(&o_chunk.logits).unwrap();
        assert_eq!(t_ref, t_chunk,
                   "greedy decode diverged after chunked prefill");
    }
}

/// The 256-token ceiling is gone at the session level: a prompt beyond
/// the largest `prefill_<P>` bucket ingests through `begin_prompt`'s
/// chunk chain and decodes normally.
#[test]
fn begin_prompt_ingests_beyond_largest_bucket() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    if session.prefill_chunk_buckets().is_empty() {
        eprintln!("skipping: artifacts predate the prefill_chunk entries");
        return;
    }
    let n = 300usize;
    let prompt: Vec<u32> = (0..n as u32).map(|i| (i * 13 + 5) % 1000).collect();
    assert!(session.prefill_bucket(n).is_err(),
            "{n} tokens should exceed the bucketed prefill");
    let before = rt.transfers().snapshot();
    let (mut gen, logits) = session.begin_prompt(&prompt).unwrap();
    let after = rt.transfers().snapshot();
    assert_eq!(gen.pos, n);
    assert_eq!(after.prefill_chunks - before.prefill_chunks, 3,
               "300 tokens should chunk as 128 + 128 + 44");
    assert!(logits.iter().all(|v| v.is_finite()));
    let t = DecodeSession::argmax(&logits).unwrap();
    let out = session.advance(&mut gen, t, EstMode::Approx).unwrap();
    assert!(out.logits.iter().all(|v| v.is_finite()));
    assert_eq!(gen.pos, n + 1);
}

/// ISSUE 5 acceptance: a prompt longer than the largest prefill bucket is
/// served TO COMPLETION through the serving core — admission no longer
/// errors, the scheduler ingests the chunks, and the full output streams.
#[test]
fn long_prompt_request_served_to_completion_through_core() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    if engine.session_for_target(4.0).prefill_chunk_buckets().is_empty() {
        eprintln!("skipping: artifacts predate the prefill_chunk entries");
        return;
    }
    let prompt = long_prompt(&engine.tokenizer, 280);
    let n_tok = engine.tokenizer.encode(&prompt).len();
    assert!(n_tok > 256, "prompt only reached {n_tok} tokens");
    let mut queue = RequestQueue::new(SchedPolicy::Fifo);
    queue.push(Request::new(1, prompt, 5, QosBudget::best_effort()));
    let mut util = UtilizationSim::constant(0.0);
    let mut faults = 0usize;
    let outcomes = ServingCore::new(&engine, SchedPolicy::Fifo)
        .run(&mut queue, &mut util, &mut |ev| {
            if matches!(ev, CoreEvent::Failed { .. } | CoreEvent::Error { .. }) {
                faults += 1;
            }
        })
        .unwrap();
    assert_eq!(faults, 0, "long prompt faulted");
    assert_eq!(outcomes.len(), 1);
    assert_eq!(outcomes[0].output_tokens, 5);
    assert!(!outcomes[0].text.is_empty());
}

/// THE regression for the headline bugfix: a poisoned queue (over-long +
/// empty-tokenization prompts around a healthy one) is driven through the
/// serving loop; the poisoned requests get terminal `CoreEvent::Error`s
/// — NOT an `Err` return that aborts the drain — and the healthy request
/// streams its full output.
#[test]
fn poisoned_admission_does_not_kill_the_loop() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    let max_len = engine.session_for_target(4.0).max_prompt_len();
    let oversized = long_prompt(&engine.tokenizer, max_len + 64);
    let mut queue = RequestQueue::new(SchedPolicy::Fifo);
    queue.push(Request::new(7, oversized, 4, QosBudget::best_effort()));
    queue.push(Request::new(8, "", 4, QosBudget::best_effort()));
    queue.push(Request::new(9, "The town of", 4, QosBudget::best_effort()));
    let mut core = ServingCore::new(&engine, SchedPolicy::Fifo);
    let mut errors: Vec<u64> = Vec::new();
    let mut done: Vec<u64> = Vec::new();
    let mut healthy_tokens = 0usize;
    // Drive the loop manually (run() consumes the core) so the rejection
    // counters stay inspectable afterwards.
    while core.has_active() || !queue.is_empty() {
        core.admit_from(&mut queue, 0.0);
        for ev in core.step().unwrap() {
            match ev {
                CoreEvent::Error { id, .. } => errors.push(id),
                CoreEvent::Done(o) => {
                    healthy_tokens = o.output_tokens;
                    done.push(o.id);
                }
                CoreEvent::Failed { id, error } => {
                    panic!("request {id} failed mid-flight: {error}")
                }
                CoreEvent::Token { .. } => {}
            }
        }
    }
    errors.sort_unstable();
    assert_eq!(errors, vec![7, 8], "poisoned ids must get Error events");
    assert_eq!(core.admit_rejects(), 2);
    assert_eq!(done, vec![9], "healthy request must complete");
    assert_eq!(healthy_tokens, 4, "healthy request's full output");
}

/// ISSUE 5 acceptance (interleave bound) + the admission-metrics
/// satellite: with one long-prompt admission and two active decodes,
/// every scheduling round advances BOTH decodes while running at most
/// one prefill chunk (asserted via the `prefill_chunks` /
/// `prefill_stall_ms` counters), and the completed request's record
/// carries the true queue/prefill/TTFT split — `ttft_ms >= queue_ms +
/// prefill_ms`, impossible under the old synchronous admission stamp
/// whenever decode rounds interleave between chunks.
#[test]
fn prefill_interleaves_one_chunk_per_round_and_splits_ttft() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let engine = match ServingEngine::load(&rt, MODEL, 5, &["4.00"]) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("skipping: engine load failed ({e:#})");
            return;
        }
    };
    let session = engine.session_for_target(4.0);
    if session.prefill_chunk_buckets().is_empty() || session.max_batch() < 2 {
        eprintln!("skipping: artifacts lack prefill_chunk or batched entries");
        return;
    }
    let config = CoreConfig { spec: false, ..CoreConfig::default() };
    let mut core =
        ServingCore::new(&engine, SchedPolicy::Fifo).with_config(config);
    core.admit_pinned(
        Request::new(1, "The town of", 40, QosBudget::best_effort()), 4.0)
        .unwrap();
    core.admit_pinned(
        Request::new(2, "The town of", 40, QosBudget::best_effort()), 4.0)
        .unwrap();
    // Step until both short prompts are decodable.
    let mut started = [false; 2];
    while !(started[0] && started[1]) {
        for ev in core.step().unwrap() {
            if let CoreEvent::Token { id, index: 0, .. } = ev {
                started[(id - 1) as usize] = true;
            }
        }
    }
    // Long prompt arrives mid-flight.
    let prompt = long_prompt(&engine.tokenizer, 280);
    assert!(engine.tokenizer.encode(&prompt).len() > 256);
    core.admit_pinned(Request::new(3, prompt, 3, QosBudget::best_effort()), 4.0)
        .unwrap();
    let chunks_at_admit = core.prefill_chunks();
    let mut r3_started = false;
    while !r3_started {
        let chunks_before = core.prefill_chunks();
        let evs = core.step().unwrap();
        let delta = core.prefill_chunks() - chunks_before;
        assert!(delta <= 1, "more than one prefill dispatch in one round");
        assert_eq!(delta, 1, "prefill made no progress this round");
        let mut got = [0usize; 2];
        for ev in &evs {
            match ev {
                CoreEvent::Token { id: 3, index: 0, .. } => r3_started = true,
                CoreEvent::Token { id, .. } if *id <= 2 => {
                    got[(*id - 1) as usize] += 1
                }
                CoreEvent::Failed { id, error }
                | CoreEvent::Error { id, error, .. } => {
                    panic!("request {id} errored: {error}")
                }
                _ => {}
            }
        }
        // The interleave bound: no decode waits more than the one chunk
        // dispatch between its tokens — both advanced this very round.
        assert_eq!(got, [1, 1], "a decode starved during prefill: {got:?}");
    }
    let long_chunks = core.prefill_chunks() - chunks_at_admit;
    assert!(long_chunks >= 2,
            "a >256-token prompt must take multiple chunks, got {long_chunks}");
    assert!(core.prefill_stall_ms() > 0.0,
            "stalling chunks must meter their wall time");
    core.drain(&mut |_| {}).unwrap();
    let rec = engine
        .metrics
        .records()
        .into_iter()
        .find(|r| r.id == 3)
        .expect("request 3 recorded");
    assert!(rec.prefill_ms > 0.0);
    assert!(
        rec.ttft_ms + 1e-6 >= rec.queue_ms + rec.prefill_ms,
        "ttft {} must cover queue {} + scheduled prefill {}",
        rec.ttft_ms, rec.queue_ms, rec.prefill_ms
    );
}

/// Long prompts evaluate for real in the task harness now, and any
/// residual skip is visible: the artifact-gated eval must report ZERO
/// skipped samples (the old code silently `continue`d past long prompts,
/// biasing Table 2 toward short ones).
#[test]
fn eval_task_reports_zero_skips() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap();
    let res = tasks::eval_task(&session, &tok, "arith", 5, EstMode::Approx)
        .unwrap();
    assert!(res.n > 0);
    assert_eq!(res.skipped, 0,
               "{} samples skipped — with chunked prefill every prompt \
                must evaluate", res.skipped);
}

/// Prefill + decode continuation through the serving path (GenState keeps
/// the prefill-produced KV on the device).
#[test]
fn prefill_then_decode() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Uniform { bits: 6 };
    let session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    let tok = Tokenizer::load(&art(&["data", "tokenizer.json"])).unwrap();

    let prompt = tok.encode("The town of");
    let (mut gen, logits) = session.begin(&prompt).unwrap();
    assert_eq!(logits.len(), session.cfg.vocab);
    assert_eq!(gen.pos, prompt.len());
    let next = DecodeSession::argmax(&logits).unwrap();
    let out = session.advance(&mut gen, next, EstMode::Approx).unwrap();
    assert!(out.logits.iter().all(|v| v.is_finite()));
    assert_eq!(gen.pos, prompt.len() + 1);
}

/// Installs a byte-budgeted KV pool on a fresh session (what
/// `ServingEngine::load` does for the whole adaptation set).
fn with_kv_pool(session: &mut DecodeSession, budget: usize) {
    let kv_len: usize = session.cfg.kv_shape().iter().product();
    let bpt = kv_len / session.cfg.max_seq.max(1) * 4;
    let pool: SharedKvPool =
        Rc::new(RefCell::new(KvPool::new(budget, bpt)));
    session.set_kv_pool(pool, "itest:4.00");
}

/// Tier-migrated generations are bit-exact against a max_seq-from-birth
/// session: decoding through a sub-max tier graph and the zero-pad
/// migration are numerically invisible, because the `arange(S) <= pos`
/// mask makes every tail slot don't-care (DESIGN.md §Memory; the same
/// invariant is pinned at the jax level in test_aot.py's tier tests).
#[test]
fn tier_migration_preserves_logits_bitwise() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    // Reference: no pool installed — born at max_seq, never migrates.
    let plain = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    // Tiered: pool installed — born at the smallest tier, migrates up.
    let mut tiered = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    with_kv_pool(&mut tiered, usize::MAX);
    let tiers = tiered.kv_tiers();
    if tiers.len() < 2 {
        eprintln!("skipping: artifacts predate the KV tier graphs");
        return;
    }
    let chunk = plain.max_prefill_chunk();
    if chunk == 0 {
        eprintln!("skipping: artifacts predate the prefill chunk graphs");
        return;
    }
    // A prompt longer than the birth tier forces a mid-stream migration
    // in the tiered session (the second chunk's bucket span overruns it).
    let birth = tiers[0];
    let prompt: Vec<u32> =
        (0..birth as u32 + 32).map(|t| 2 + t % 61).collect();
    let run = |session: &DecodeSession| {
        let mut gen = session.begin_empty().unwrap();
        let mut logits = None;
        let mut at = 0usize;
        while at < prompt.len() {
            let n = chunk.min(prompt.len() - at);
            logits = session
                .prefill_advance(&mut gen, &prompt[at..at + n],
                                 at + n == prompt.len())
                .unwrap();
            at += n;
        }
        let first = DecodeSession::argmax(logits.as_ref().unwrap()).unwrap();
        let out = session.advance(&mut gen, first, EstMode::Approx).unwrap();
        (logits.unwrap(), first, out.logits)
    };
    let before = rt.transfers().snapshot();
    let (l_ref, t_ref, d_ref) = run(&plain);
    let mid = rt.transfers().snapshot();
    assert_eq!(mid.kv_migrations, before.kv_migrations,
               "the pool-less reference must never migrate");
    let (l_tier, t_tier, d_tier) = run(&tiered);
    assert!(rt.transfers().snapshot().kv_migrations > mid.kv_migrations,
            "the tiered generation must migrate at least once");
    assert_eq!(t_ref, t_tier, "first sampled token must match");
    assert_eq!(l_ref, l_tier, "prefill logits must be bit-exact");
    assert_eq!(d_ref, d_tier,
               "post-migration decode logits must be bit-exact");
}

/// Shared-prefix prefill cache: the second of two requests with an
/// identical prompt prefix clones the published prefix KV (copy-on-write)
/// and skips its prefix chunks, producing bit-identical first-token
/// logits while `prefix_hits`/`prefix_prefills_saved` advance.
#[test]
fn shared_prefix_hit_reuses_kv_and_matches_first_token() {
    require_artifacts!();
    let rt = Arc::new(Runtime::new().unwrap());
    let assets = ModelAssets::load(MODEL).unwrap();
    let manifest = Manifest::load().unwrap();
    let m = Method::Dpllm { tag: "4.00".into() };
    let mut session = build_session(&rt, &assets, &manifest, 5, &m).unwrap();
    with_kv_pool(&mut session, usize::MAX);
    let chunk = session.max_prefill_chunk();
    if chunk == 0 {
        eprintln!("skipping: artifacts predate the prefill chunk graphs");
        return;
    }
    // One full quantum plus a tail: the shareable prefix is the first
    // `chunk` tokens; the final chunk stays uncached, so a hit still
    // dispatches the graph that yields the first-token logits.
    let prompt: Vec<u32> =
        (0..chunk as u32 + 32).map(|t| 3 + t % 53).collect();
    assert!(session.begin_from_prefix(&prompt).is_none(),
            "cold cache must miss");
    // Request A: full chunked prefill, publishing at the quantum boundary
    // (exactly what ServingCore::prefill_step does).
    let mut ga = session.begin_empty().unwrap();
    let none = session
        .prefill_advance(&mut ga, &prompt[..chunk], false)
        .unwrap();
    assert!(none.is_none(), "want_logits=false skips the logits download");
    session.prefix_publish(&mut ga, &prompt, chunk);
    let la = session
        .prefill_advance(&mut ga, &prompt[chunk..], true)
        .unwrap()
        .expect("final chunk returns logits");
    // Request B: prefix hit — only the final chunk is dispatched.
    let before = rt.transfers().snapshot();
    let (mut gb, len) = session
        .begin_from_prefix(&prompt)
        .expect("published prefix must hit");
    assert_eq!(len, chunk);
    assert_eq!(gb.pos, chunk);
    let lb = session
        .prefill_advance(&mut gb, &prompt[chunk..], true)
        .unwrap()
        .expect("final chunk returns logits");
    let after = rt.transfers().snapshot();
    assert_eq!(after.prefix_hits, before.prefix_hits + 1);
    assert!(after.prefix_prefills_saved > before.prefix_prefills_saved,
            "a hit must count its avoided prefix chunks");
    assert_eq!(la, lb, "first-token logits must be bit-identical");
    // Copy-on-write: each generation's next dispatch output is private,
    // so both continue independently from the shared prefix.
    let t0 = DecodeSession::argmax(&la).unwrap();
    let oa = session.advance(&mut ga, t0, EstMode::Approx).unwrap();
    let ob = session.advance(&mut gb, t0, EstMode::Approx).unwrap();
    assert_eq!(oa.logits, ob.logits);
}
